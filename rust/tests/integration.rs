//! End-to-end integration: both backends, real corpora, exact-count
//! verification against an independent single-threaded oracle.

use std::collections::{BTreeSet, HashMap};
use std::path::PathBuf;
use std::sync::Arc;

use mr1s::error::Error;
use mr1s::mapreduce::kv::Value;
use mr1s::mapreduce::{BackendKind, Job, JobConfig, RouteConfig, UseCase, ValueKind};
use mr1s::pipeline::{oracle, plans, Pipeline};
use mr1s::sim::CostModel;
use mr1s::usecases::{
    self, DistinctShards, EquiJoin, InvertedIndex, LengthHistogram, MeanLength, SecondarySort,
    TfIdfScore, TopK, WordCount,
};
use mr1s::workload::{generate_corpus, skew_factors, CorpusSpec, SkewSpec};

fn tmppath(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("mr1s-it-{name}-{}", std::process::id()))
}

/// Collapse a job result into a `key -> u64` map (inline-u64 use-cases).
fn counts_map(result: Vec<(Vec<u8>, Value)>) -> HashMap<Vec<u8>, u64> {
    result
        .into_iter()
        .map(|(k, v)| {
            let c = v.as_u64().expect("inline-u64 value");
            (k, c)
        })
        .collect()
}

/// Independent oracle: single pass over the whole file, no framework
/// code except the shared tokenizer.
fn oracle_wordcount(path: &PathBuf) -> HashMap<Vec<u8>, u64> {
    let data = std::fs::read(path).unwrap();
    let mut counts: HashMap<Vec<u8>, u64> = HashMap::new();
    for line in data.split(|&b| b == b'\n') {
        for tok in WordCount::tokens(line) {
            *counts.entry(tok).or_insert(0) += 1;
        }
    }
    counts
}

fn small_config(input: PathBuf) -> JobConfig {
    JobConfig {
        input,
        task_size: 16 << 10,
        win_size: 16 << 10,
        chunk_size: 4 << 10,
        ..Default::default()
    }
}

fn run_and_check(backend: BackendKind, nranks: usize, cfg: JobConfig) {
    let oracle = oracle_wordcount(&cfg.input);
    let job = Job::new(Arc::new(WordCount), cfg).unwrap();
    let out = job.run(backend, nranks, CostModel::default()).unwrap();

    assert_eq!(out.report.unique_keys as usize, oracle.len(), "unique key count");
    let total: u64 = oracle.values().sum();
    assert_eq!(out.report.total_count, total, "total occurrences");
    let got = counts_map(out.result);
    assert_eq!(got.len(), oracle.len());
    for (word, count) in &oracle {
        assert_eq!(got.get(word), Some(count), "word {:?}", String::from_utf8_lossy(word));
    }
    assert!(out.report.elapsed_ns > 0);
}

fn corpus(name: &str, bytes: u64, seed: u64) -> PathBuf {
    let p = tmppath(name);
    generate_corpus(&p, &CorpusSpec { bytes, seed, ..Default::default() }).unwrap();
    p
}

#[test]
fn mr1s_exact_counts_various_rank_counts() {
    let p = corpus("1s-ranks", 200_000, 1);
    for nranks in [1, 2, 3, 4, 8] {
        run_and_check(BackendKind::OneSided, nranks, small_config(p.clone()));
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn mr2s_exact_counts_various_rank_counts() {
    let p = corpus("2s-ranks", 200_000, 2);
    for nranks in [1, 2, 3, 4, 8] {
        run_and_check(BackendKind::TwoSided, nranks, small_config(p.clone()));
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn both_backends_agree_with_each_other() {
    let p = corpus("agree", 150_000, 3);
    let job1 = Job::new(Arc::new(WordCount), small_config(p.clone())).unwrap();
    let job2 = Job::new(Arc::new(WordCount), small_config(p.clone())).unwrap();
    let r1 = job1.run(BackendKind::OneSided, 4, CostModel::default()).unwrap();
    let r2 = job2.run(BackendKind::TwoSided, 4, CostModel::default()).unwrap();
    let m1 = counts_map(r1.result);
    let m2 = counts_map(r2.result);
    assert_eq!(m1, m2);
    std::fs::remove_file(&p).ok();
}

#[test]
fn unbalanced_runs_produce_identical_counts() {
    // The paper's imbalance is temporal (same task computed repeatedly,
    // input read once): outputs must match the balanced run exactly.
    let p = corpus("skew", 150_000, 4);
    let balanced = small_config(p.clone());
    let ntasks = (std::fs::metadata(&p).unwrap().len() as usize).div_ceil(balanced.task_size);
    let skewed = JobConfig {
        skew: skew_factors(SkewSpec::paper_unbalanced(), ntasks, 7),
        ..balanced.clone()
    };
    let out_b = Job::new(Arc::new(WordCount), balanced)
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();
    let out_s = Job::new(Arc::new(WordCount), skewed)
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();
    let mb = counts_map(out_b.result);
    let ms = counts_map(out_s.result);
    assert_eq!(mb, ms);
    // ... but the skewed run must be slower.
    assert!(out_s.report.elapsed_ns > out_b.report.elapsed_ns);
    std::fs::remove_file(&p).ok();
}

#[test]
fn scalar_and_kernel_paths_agree() {
    let p = corpus("paths", 120_000, 5);
    let kernel_cfg = JobConfig { use_kernel: true, ..small_config(p.clone()) };
    let scalar_cfg = JobConfig { use_kernel: false, ..small_config(p.clone()) };
    let rk = Job::new(Arc::new(WordCount), kernel_cfg)
        .unwrap()
        .run(BackendKind::OneSided, 3, CostModel::default())
        .unwrap();
    let rs = Job::new(Arc::new(WordCount), scalar_cfg)
        .unwrap()
        .run(BackendKind::OneSided, 3, CostModel::default())
        .unwrap();
    let mk = counts_map(rk.result);
    let ms = counts_map(rs.result);
    assert_eq!(mk, ms);
    std::fs::remove_file(&p).ok();
}

#[test]
fn checkpointed_run_matches_and_writes_files() {
    let p = corpus("ckpt", 100_000, 6);
    let dir = tmppath("ckpt-dir");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = JobConfig {
        checkpoints: true,
        checkpoint_dir: dir.clone(),
        ..small_config(p.clone())
    };
    let oracle = oracle_wordcount(&p);
    let out = Job::new(Arc::new(WordCount), cfg)
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();
    assert_eq!(out.report.unique_keys as usize, oracle.len());
    // Every rank must have produced a checkpoint file with content.
    for r in 0..4 {
        let f = dir.join(format!("mr1s-ckpt-{r}.bin"));
        assert!(f.exists(), "missing checkpoint {f:?}");
    }
    std::fs::remove_file(&p).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn inverted_index_builds_true_posting_lists() {
    let p = corpus("invidx", 80_000, 8);
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let job = Job::new(Arc::new(InvertedIndex), small_config(p.clone())).unwrap();
        let out = job.run(backend, 4, CostModel::default()).unwrap();
        // Oracle: per-token set of containing shards.
        let data = std::fs::read(&p).unwrap();
        let mut oracle: HashMap<Vec<u8>, BTreeSet<u32>> = HashMap::new();
        for line in data.split(|&b| b == b'\n') {
            if line.is_empty() {
                continue;
            }
            let shard = InvertedIndex::shard(line);
            for tok in WordCount::tokens(line) {
                oracle.entry(tok).or_default().insert(shard);
            }
        }
        let mut seen_shards: BTreeSet<u32> = BTreeSet::new();
        let mut got = 0usize;
        for (key, value) in out.result {
            let postings = InvertedIndex::decode_postings(value.as_bytes().unwrap());
            // Posting lists must be strictly increasing (sorted, deduped).
            assert!(postings.windows(2).all(|w| w[0] < w[1]), "unsorted postings");
            let want = oracle.get(&key).unwrap_or_else(|| {
                panic!("unexpected key {:?}", String::from_utf8_lossy(&key))
            });
            let got_set: BTreeSet<u32> = postings.iter().copied().collect();
            assert_eq!(&got_set, want, "postings of {:?}", String::from_utf8_lossy(&key));
            seen_shards.extend(postings);
            got += 1;
        }
        assert_eq!(got, oracle.len(), "key count");
        // The whole point of the refactor: more than 64 shards exist.
        assert!(seen_shards.len() > 64, "only {} shards used", seen_shards.len());
        assert!(seen_shards.iter().any(|&s| s >= 64), "no shard id beyond the old bitmask cap");
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn mean_length_matches_oracle_on_both_backends() {
    let p = corpus("meanlen", 80_000, 13);
    let data = std::fs::read(&p).unwrap();
    let mut oracle: HashMap<Vec<u8>, (u64, u64)> = HashMap::new();
    for line in data.split(|&b| b == b'\n') {
        for tok in WordCount::tokens(line) {
            let e = oracle.entry(tok).or_insert((0, 0));
            e.0 += 1;
            e.1 += line.len() as u64;
        }
    }
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let job = Job::new(Arc::new(MeanLength), small_config(p.clone())).unwrap();
        let out = job.run(backend, 4, CostModel::default()).unwrap();
        assert_eq!(out.report.unique_keys as usize, oracle.len());
        for (key, value) in out.result {
            let got = MeanLength::decode(value.as_bytes().unwrap());
            let want = oracle[&key];
            assert_eq!(got, want, "aggregate of {:?}", String::from_utf8_lossy(&key));
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn length_histogram_matches_oracle() {
    let p = corpus("hist", 80_000, 9);
    let job = Job::new(Arc::new(LengthHistogram), small_config(p.clone())).unwrap();
    let out = job.run(BackendKind::TwoSided, 3, CostModel::default()).unwrap();
    let data = std::fs::read(&p).unwrap();
    let mut oracle: HashMap<Vec<u8>, u64> = HashMap::new();
    for line in data.split(|&b| b == b'\n') {
        for tok in WordCount::tokens(line) {
            *oracle.entry(LengthHistogram::key_for(tok.len())).or_insert(0) += 1;
        }
    }
    let got = counts_map(out.result);
    assert_eq!(got, oracle);
    std::fs::remove_file(&p).ok();
}

#[test]
fn job_stealing_exact_counts_and_speedup_under_skew() {
    // §6 future work: stealing must preserve exactness (every task runs
    // exactly once, whoever claims it) and shed straggler tails.
    let p = corpus("steal", 300_000, 12);
    let base = small_config(p.clone());
    let ntasks = (std::fs::metadata(&p).unwrap().len() as usize).div_ceil(base.task_size);
    // One rank owns all the heavy tasks: worst-case static imbalance.
    let skew: Vec<f64> =
        (0..ntasks).map(|t| if t % 4 == 0 { 6.0 } else { 1.0 }).collect();
    let mk = |stealing: bool| JobConfig { skew: skew.clone(), job_stealing: stealing, ..base.clone() };

    let oracle = oracle_wordcount(&p);
    let plain = Job::new(Arc::new(WordCount), mk(false))
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();
    let stolen = Job::new(Arc::new(WordCount), mk(true))
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();

    let mp = counts_map(plain.result);
    let ms = counts_map(stolen.result);
    assert_eq!(mp.len(), oracle.len());
    assert_eq!(ms, mp, "stealing changed the results");
    assert!(
        stolen.report.elapsed_ns < plain.report.elapsed_ns,
        "stealing must shed the straggler: {} !< {}",
        stolen.report.elapsed_ns,
        plain.report.elapsed_ns
    );
    std::fs::remove_file(&p).ok();
}

#[test]
fn topk_matches_oracle_on_both_backends() {
    let p = corpus("topk", 80_000, 14);
    let want = oracle::topk(&std::fs::read(&p).unwrap());
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let job = Job::new(Arc::new(TopK), small_config(p.clone())).unwrap();
        let out = job.run(backend, 4, CostModel::default()).unwrap();
        assert_eq!(out.report.unique_keys as usize, want.len());
        for (key, value) in out.result {
            let got = TopK::decode(value.as_bytes().unwrap());
            assert!(got.len() <= TopK::K);
            assert_eq!(got, want[&key], "top-k of {:?}", String::from_utf8_lossy(&key));
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn tfidf_pipeline_matches_oracle_on_both_backends() {
    let p = corpus("pipe-tfidf", 60_000, 21);
    let want = oracle::tfidf(&std::fs::read(&p).unwrap());
    assert!(!want.is_empty());
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let plan = plans::tfidf_plan(p.clone(), backend);
        let pipe = Pipeline::new(plan, 4, CostModel::default(), small_config(p.clone())).unwrap();
        let out = pipe.run().unwrap();
        assert_eq!(out.stages.len(), 3);
        assert_eq!(out.result.len(), want.len(), "{}", backend.name());
        for (key, value) in &out.result {
            let scores = TfIdfScore::decode_scores(value.as_bytes().unwrap());
            assert_eq!(
                want.get(key),
                Some(&scores),
                "{}: scores of {:?}",
                backend.name(),
                String::from_utf8_lossy(key)
            );
        }
        std::fs::remove_dir_all(pipe.workdir()).ok();
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn join_pipeline_matches_oracle_on_both_backends() {
    let p = corpus("pipe-join", 60_000, 23);
    let want = oracle::join(&std::fs::read(&p).unwrap());
    assert!(!want.is_empty());
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let plan = plans::join_plan(p.clone(), backend);
        let pipe = Pipeline::new(plan, 4, CostModel::default(), small_config(p.clone())).unwrap();
        let out = pipe.run().unwrap();
        assert_eq!(out.result.len(), want.len(), "{}", backend.name());
        for (key, value) in &out.result {
            let pairs = EquiJoin::decode_pairs(value.as_bytes().unwrap());
            let (count, (occ, total)) = want[key.as_slice()];
            assert_eq!(
                pairs,
                vec![(count.to_le_bytes().to_vec(), MeanLength::encode(occ, total).to_vec())],
                "{}: join of {:?}",
                backend.name(),
                String::from_utf8_lossy(key)
            );
        }
        std::fs::remove_dir_all(pipe.workdir()).ok();
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn pipeline_stages_overlap_on_mr1s() {
    // The acceptance shape of the stage boundary: stage N+1's first
    // input read must be issued before stage N's last rank finishes
    // Combine (prefetch overlaps the producer's tail), while the read
    // itself cannot complete before the spilled input is durable.
    let p = corpus("pipe-overlap", 400_000, 22);
    let plan = plans::tfidf_plan(p.clone(), BackendKind::OneSided);
    let pipe = Pipeline::new(plan, 4, CostModel::default(), small_config(p.clone())).unwrap();
    let out = pipe.run().unwrap();

    let (issue, prev_combine_end) = out.handoff(1).expect("stage 1 recorded a read issue");
    assert!(
        issue < prev_combine_end,
        "stage 1 first read (vt {issue}) must be issued before stage 0's last rank \
         finishes Combine (vt {prev_combine_end})"
    );
    // The spill is charged on the virtual clock: stage 1's input only
    // became durable after stage 0's root had its result.
    assert!(out.stages[1].input_ready_vt > 0);
    // Absolute pipeline time: later stages end no earlier than earlier.
    assert!(out.stages[1].report.elapsed_ns >= out.stages[0].report.elapsed_ns);
    assert!(out.elapsed_ns >= out.stages[2].report.elapsed_ns);
    std::fs::remove_dir_all(pipe.workdir()).ok();
    std::fs::remove_file(&p).ok();
}

/// Collapse a job result into a `key -> value` map (any tier).
fn value_map(result: Vec<(Vec<u8>, Value)>) -> HashMap<Vec<u8>, Value> {
    result.into_iter().collect()
}

#[test]
fn planned_route_lowers_reduce_imbalance_under_zipf() {
    // The acceptance shape of the shuffle planner: on a zipfian corpus
    // whose reduce load is occurrence-weighted (local reduce off, so
    // every token occurrence crosses the shuffle), the planned route
    // must lower max/mean per-rank reduce bytes versus modulo while
    // producing identical results.
    let p = tmppath("route-zipf");
    generate_corpus(&p, &CorpusSpec { bytes: 400_000, zipf_s: 1.2, seed: 31, ..Default::default() })
        .unwrap();
    let base = JobConfig { local_reduce: false, ..small_config(p.clone()) };
    let oracle = oracle_wordcount(&p);
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let run = |route: RouteConfig| {
            Job::new(Arc::new(WordCount), JobConfig { route, ..base.clone() })
                .unwrap()
                .run(backend, 4, CostModel::default())
                .unwrap()
        };
        let modulo = run(RouteConfig::Modulo);
        let planned = run(RouteConfig::Planned { split: 4 });

        // Identical results either way (and both oracle-exact).
        let mm = counts_map(modulo.result);
        let mp = counts_map(planned.result);
        assert_eq!(mm.len(), oracle.len(), "{}", backend.name());
        assert_eq!(mm, mp, "{}: routes disagree", backend.name());

        // The planner must measurably flatten the reduce load.
        let imb_modulo = modulo.report.reduce_max_over_mean();
        let imb_planned = planned.report.reduce_max_over_mean();
        assert!(
            imb_planned < imb_modulo,
            "{}: planned {imb_planned:.3} !< modulo {imb_modulo:.3}",
            backend.name()
        );
        // Planned-vs-actual is reported only for the planned run.
        assert!(modulo.report.planned_reduce_bytes_per_rank.is_none());
        let planned_loads = planned.report.planned_reduce_bytes_per_rank.as_ref().unwrap();
        assert_eq!(planned_loads.len(), 4);
        assert!(planned_loads.iter().sum::<u64>() > 0);
        assert!(planned.report.planned_reduce_max_over_mean().unwrap() >= 1.0);
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn every_usecase_is_oracle_equal_across_routes_and_backends() {
    // Split-key re-combination must be invisible: for every registered
    // use-case (including the distinct HLL sketch, whose lane-wise max
    // is the split-key stress test) the planned route — with splitting
    // forced on — produces exactly the modulo route's output on both
    // backends.  The coded route raises the stakes further: every map
    // task runs on r ranks and heavy buckets cross the wire as XOR
    // packets, yet after decode + Combine the output must still be
    // byte-identical for every replication factor.
    let p = corpus("route-usecases", 60_000, 33);
    for entry in usecases::REGISTRY {
        for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
            let run = |route: RouteConfig| {
                Job::new((entry.make)(), JobConfig { route, ..small_config(p.clone()) })
                    .unwrap()
                    .run(backend, 4, CostModel::default())
                    .unwrap()
            };
            let modulo = value_map(run(RouteConfig::Modulo).result);
            let planned = value_map(run(RouteConfig::Planned { split: 3 }).result);
            assert_eq!(
                modulo,
                planned,
                "{} on {}: planned route changed the result",
                entry.name,
                backend.name()
            );
            for r in 2..=4 {
                let coded = value_map(run(RouteConfig::Coded { r }).result);
                assert_eq!(
                    modulo,
                    coded,
                    "{} on {}: coded route r={r} changed the result",
                    entry.name,
                    backend.name()
                );
            }
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn coded_route_cuts_wire_bytes_on_shuffle_bound_zipf() {
    // The tentpole claim at integration scale: with local reduce off so
    // every occurrence crosses the shuffle, the coded route must move
    // measurably fewer bytes on the wire than its own logical shuffle
    // volume while staying oracle-exact.
    let p = tmppath("coded-zipf");
    generate_corpus(&p, &CorpusSpec { bytes: 400_000, zipf_s: 1.2, seed: 37, ..Default::default() })
        .unwrap();
    let oracle = oracle_wordcount(&p);
    let base = JobConfig { local_reduce: false, ..small_config(p.clone()) };
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let out = Job::new(
            Arc::new(WordCount),
            JobConfig { route: RouteConfig::Coded { r: 2 }, ..base.clone() },
        )
        .unwrap()
        .run(backend, 8, CostModel::default())
        .unwrap();
        assert_eq!(counts_map(out.result), oracle, "{}", backend.name());
        let wire = out.report.shuffle_wire_bytes();
        let logical = out.report.shuffle_logical_bytes();
        assert!(wire > 0, "{}: no wire bytes recorded", backend.name());
        assert!(
            out.report.shuffle_coding_gain() > 1.2,
            "{}: coding gain {:.2} (wire {wire}, logical {logical})",
            backend.name(),
            out.report.shuffle_coding_gain()
        );
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn coded_replication_beyond_world_size_is_typed_error() {
    let p = corpus("coded-reject", 30_000, 35);
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let job = Job::new(
            Arc::new(WordCount),
            JobConfig { route: RouteConfig::Coded { r: 5 }, ..small_config(p.clone()) },
        )
        .unwrap();
        let err = job.run(backend, 4, CostModel::default()).unwrap_err();
        match err {
            Error::Config(msg) => {
                assert!(
                    msg.contains("exceeds world size"),
                    "{}: unexpected message {msg:?}",
                    backend.name()
                );
            }
            other => panic!("{}: expected Error::Config, got {other}", backend.name()),
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn distinct_matches_exact_oracle_on_both_backends() {
    let p = corpus("distinct", 80_000, 15);
    let data = std::fs::read(&p).unwrap();
    // Exact oracle: per-token set of containing shards, plus the
    // register set an order-free replay of those shards produces.
    let mut exact: HashMap<Vec<u8>, BTreeSet<u32>> = HashMap::new();
    for line in data.split(|&b| b == b'\n') {
        if line.is_empty() {
            continue;
        }
        let shard = InvertedIndex::shard(line);
        for tok in WordCount::tokens(line) {
            exact.entry(tok).or_default().insert(shard);
        }
    }
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let job = Job::new(Arc::new(DistinctShards), small_config(p.clone())).unwrap();
        let out = job.run(backend, 4, CostModel::default()).unwrap();
        assert_eq!(out.report.unique_keys as usize, exact.len(), "{}", backend.name());
        for (key, value) in out.result {
            let regs = value.as_bytes().unwrap();
            let shards = &exact[&key];
            // Registers are bit-exact: lane-wise max is order-free, so
            // the job's merge tree must reproduce a sequential replay.
            let mut want = vec![0u8; DistinctShards::M];
            for &s in shards {
                DistinctShards::insert(&mut want, s);
            }
            assert_eq!(regs, &want[..], "{}: registers of {:?}", backend.name(),
                String::from_utf8_lossy(&key));
            // And the estimate tracks the exact distinct count.  The
            // correctness claim is the register equality above; this
            // bound is estimator sanity (m = 64 has ~13% standard error
            // in the harmonic regime plus transition-zone bias, so the
            // envelope is deliberately loose).
            let est = DistinctShards::estimate(regs);
            let truth = shards.len() as f64;
            assert!(
                (est - truth).abs() <= (truth * 0.5).max(4.0),
                "{}: estimate {est:.1} vs exact {truth} for {:?}",
                backend.name(),
                String::from_utf8_lossy(&key)
            );
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn pipeline_with_stealing_and_planned_route_matches_oracle() {
    // Two follow-ons riding the same plumbing: job stealing now works
    // inside staged pipeline runs (the claim gate paces against the
    // stage's start, not virtual zero), and every stage re-plans its
    // shuffle when the planned route is on.
    let p = corpus("pipe-steal-route", 60_000, 25);
    let want = oracle::tfidf(&std::fs::read(&p).unwrap());
    let base = JobConfig {
        job_stealing: true,
        route: RouteConfig::Planned { split: 2 },
        ..small_config(p.clone())
    };
    let plan = plans::tfidf_plan(p.clone(), BackendKind::OneSided);
    let pipe = Pipeline::new(plan, 4, CostModel::default(), base).unwrap();
    let out = pipe.run().unwrap();
    assert_eq!(out.result.len(), want.len());
    for (key, value) in &out.result {
        let scores = TfIdfScore::decode_scores(value.as_bytes().unwrap());
        assert_eq!(want.get(key), Some(&scores), "scores of {:?}",
            String::from_utf8_lossy(key));
    }
    // Each stage planned its own shuffle.
    for stage in &out.stages {
        assert!(
            stage.report.planned_reduce_bytes_per_rank.is_some(),
            "stage {} did not re-plan",
            stage.name
        );
    }
    std::fs::remove_dir_all(pipe.workdir()).ok();
    std::fs::remove_file(&p).ok();
}

/// A deliberately unbounded variable-width reducer: every token appends
/// an 8 KiB chunk to one hot key, overflowing `MAX_VALUE_LEN` fast.
struct UnboundedConcat;

impl UseCase for UnboundedConcat {
    fn name(&self) -> &'static str {
        "unbounded-concat"
    }

    fn value_kind(&self) -> ValueKind {
        ValueKind::Variable
    }

    fn map_record(&self, record: &[u8], emit: &mut dyn FnMut(&[u8], &[u8])) {
        let chunk = [7u8; 8192];
        for _ in WordCount::tokens(record) {
            emit(b"hot", &chunk);
        }
    }

    fn reduce(&self, acc: &mut Vec<u8>, incoming: &[u8]) {
        acc.extend_from_slice(incoming);
    }
}

#[test]
fn variable_values_past_the_u16_cap_roundtrip_via_u32_escape() {
    // The extension-header escape: an accumulator that outgrows the
    // classic u16 value-length field (here ~1.3 MiB on one hot key) must
    // now cross the wire and come back byte-exact instead of failing
    // with ValueOverflow — on both backends, single- and multi-rank.
    let p = tmppath("bigvalue");
    let mut text = String::new();
    for _ in 0..40 {
        text.push_str("spill spill spill spill\n");
    }
    std::fs::write(&p, text).unwrap();
    let want_len = 40 * 4 * 8192usize;
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        for nranks in [1, 3] {
            let job = Job::new(Arc::new(UnboundedConcat), small_config(p.clone())).unwrap();
            let out = job.run(backend, nranks, CostModel::default()).unwrap();
            let got = value_map(out.result);
            let v = got
                .get(b"hot".as_slice())
                .unwrap_or_else(|| panic!("{}: hot key missing", backend.name()))
                .as_bytes()
                .unwrap();
            assert!(v.len() > 65_535, "{}: value must exceed the u16 cap", backend.name());
            assert_eq!(v.len(), want_len, "{} n={nranks}", backend.name());
            assert!(v.iter().all(|&b| b == 7), "{} n={nranks}: bytes differ", backend.name());
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn tiny_input_single_task() {
    let p = tmppath("tiny");
    std::fs::write(&p, b"one two two three three three\n").unwrap();
    let cfg = small_config(p.clone());
    let job = Job::new(Arc::new(WordCount), cfg).unwrap();
    let out = job.run(BackendKind::OneSided, 4, CostModel::default()).unwrap();
    let got = counts_map(out.result);
    assert_eq!(got.get(b"one".as_slice()), Some(&1));
    assert_eq!(got.get(b"two".as_slice()), Some(&2));
    assert_eq!(got.get(b"three".as_slice()), Some(&3));
    std::fs::remove_file(&p).ok();
}

#[test]
fn report_phases_cover_elapsed_time() {
    let p = corpus("phases", 100_000, 10);
    let job = Job::new(Arc::new(WordCount), small_config(p.clone())).unwrap();
    let out = job.run(BackendKind::OneSided, 4, CostModel::default()).unwrap();
    for (b, &elapsed) in out.report.breakdowns.iter().zip(&out.report.rank_elapsed_ns) {
        let sum = b.io_ns + b.map_ns + b.local_reduce_ns + b.reduce_ns + b.combine_ns
            + b.wait_ns
            + b.checkpoint_ns;
        assert!(sum <= elapsed, "phases {sum} exceed elapsed {elapsed}");
        assert!(sum * 10 >= elapsed * 5, "phases {sum} cover <50% of {elapsed}");
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn memory_is_tracked() {
    let p = corpus("mem", 150_000, 11);
    let job = Job::new(Arc::new(WordCount), small_config(p.clone())).unwrap();
    let out = job.run(BackendKind::OneSided, 2, CostModel::default()).unwrap();
    assert!(out.report.peak_memory_bytes > 0);
    assert!(!out.report.memory_series.is_empty());
    std::fs::remove_file(&p).ok();
}

// ---- structured tracing (DESIGN.md §9) ----------------------------------

/// The three shuffle routes the trace invariants must hold under.
fn all_routes() -> [RouteConfig; 3] {
    [
        RouteConfig::Modulo,
        RouteConfig::Planned { split: RouteConfig::DEFAULT_SPLIT },
        RouteConfig::Coded { r: 2 },
    ]
}

#[test]
fn wait_causes_sum_to_wait_ns_on_every_rank() {
    use mr1s::metrics::tracer::{self, op};
    let p = corpus("trace-sum", 150_000, 12);
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        for route in all_routes() {
            let cfg = JobConfig { route, ..small_config(p.clone()) };
            let out = Job::new(Arc::new(WordCount), cfg)
                .unwrap()
                .run(backend, 4, CostModel::default())
                .unwrap();
            assert_eq!(out.report.spans.len(), 4, "one span vec per rank");
            for (rank, (spans, b)) in
                out.report.spans.iter().zip(&out.report.breakdowns).enumerate()
            {
                let ctx = format!("{} {route:?} rank {rank}", backend.name());
                let wait_sum: u64 =
                    spans.iter().filter(|s| s.op == op::WAIT).map(|s| s.dur_ns()).sum();
                assert_eq!(wait_sum, b.wait_ns, "wait spans != wait_ns ({ctx})");
                // Every wait span carries a cause, so the by-cause
                // decomposition covers the same total.
                let by_cause = tracer::wait_by_cause_ns(spans);
                assert_eq!(by_cause.values().sum::<u64>(), b.wait_ns, "{ctx}");
                assert!(!by_cause.contains_key("unattributed"), "{ctx}");
            }
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn trace_phase_slices_reproduce_breakdowns_exactly() {
    use mr1s::metrics::PhaseBreakdown;
    let p = corpus("trace-phase", 150_000, 13);
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        for route in all_routes() {
            let cfg = JobConfig { route, ..small_config(p.clone()) };
            let out = Job::new(Arc::new(WordCount), cfg)
                .unwrap()
                .run(backend, 4, CostModel::default())
                .unwrap();
            for (rank, (tl, want)) in
                out.report.timelines.iter().zip(&out.report.breakdowns).enumerate()
            {
                let got = PhaseBreakdown::from_events(tl);
                let ctx = format!("{} {route:?} rank {rank}", backend.name());
                assert_eq!(got.io_ns, want.io_ns, "{ctx}");
                assert_eq!(got.map_ns, want.map_ns, "{ctx}");
                assert_eq!(got.local_reduce_ns, want.local_reduce_ns, "{ctx}");
                assert_eq!(got.reduce_ns, want.reduce_ns, "{ctx}");
                assert_eq!(got.combine_ns, want.combine_ns, "{ctx}");
                assert_eq!(got.wait_ns, want.wait_ns, "{ctx}");
                assert_eq!(got.checkpoint_ns, want.checkpoint_ns, "{ctx}");
            }
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn crit_path_total_equals_elapsed() {
    let p = corpus("trace-crit", 150_000, 14);
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        for route in all_routes() {
            let cfg = JobConfig { route, ..small_config(p.clone()) };
            let out = Job::new(Arc::new(WordCount), cfg)
                .unwrap()
                .run(backend, 4, CostModel::default())
                .unwrap();
            let crit = out.report.crit_path();
            let ctx = format!("{} {route:?}", backend.name());
            assert_eq!(crit.total_ns(), out.report.elapsed_ns, "{ctx}");
            assert!(!crit.segments.is_empty(), "{ctx}");
            // The rendered summary carries the chain.
            assert!(out.report.summary().contains("crit-path="), "{ctx}");
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn chrome_trace_export_is_well_formed_and_complete() {
    use mr1s::metrics::tracer;
    let p = corpus("trace-json", 150_000, 15);
    let cfg = JobConfig {
        route: RouteConfig::Planned { split: RouteConfig::DEFAULT_SPLIT },
        ..small_config(p.clone())
    };
    let out = Job::new(Arc::new(WordCount), cfg)
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();
    let json = tracer::chrome_trace_json(&out.report.timelines, &out.report.spans);
    assert!(json.starts_with("{\"traceEvents\":["));
    assert!(json.trim_end().ends_with("}"));
    // One named track per rank.
    for rank in 0..4 {
        assert!(json.contains(&format!("\"name\":\"rank {rank}\"")), "rank {rank} track");
    }
    // Phase slices, op slices, attributed waits, and flow arrows all
    // present (a planned MR-1S run exercises every category).
    for needle in
        ["\"cat\":\"phase\"", "\"cat\":\"op\"", "\"cat\":\"wait\"", "\"ph\":\"s\"", "\"ph\":\"f\"", "\"cause\":\"status-wait\""]
    {
        assert!(json.contains(needle), "missing {needle}");
    }
    // Braces balance (no serde available; structural smoke check).
    let open = json.matches('{').count();
    let close = json.matches('}').count();
    assert_eq!(open, close);
    std::fs::remove_file(&p).ok();
}

#[test]
fn trace_stats_and_mem_hwm_surface_in_report() {
    let p = corpus("trace-stats", 150_000, 16);
    let out = Job::new(Arc::new(WordCount), small_config(p.clone()))
        .unwrap()
        .run(BackendKind::OneSided, 2, CostModel::default())
        .unwrap();
    let stats = out.report.trace_stats();
    assert!(!stats.per_op.is_empty(), "protocol ops must be recorded");
    assert_eq!(
        stats.attributed_wait_ns(),
        out.report.breakdowns.iter().map(|b| b.wait_ns).sum::<u64>(),
    );
    assert!(out.report.peak_memory_bytes > 0);
    assert!(out.report.mem_hwm_vt_ns <= out.report.elapsed_ns);
    assert!(out.report.summary().contains("mem-hwm="));
    std::fs::remove_file(&p).ok();
}

// ---- fault injection & recovery (DESIGN.md §10) --------------------------

#[test]
fn kill_recovery_is_oracle_identical_for_every_usecase() {
    // The acceptance matrix: kill a rank in either phase, on either
    // backend, for every registered use-case — the job must complete on
    // the survivors with a result key-for-key identical to the
    // fault-free oracle, and report a nonzero recovery breakdown whose
    // components equal the wait time attributed to their causes.
    use mr1s::metrics::tracer::{op, WaitCause};
    let p = corpus("faults-matrix", 60_000, 41);
    let dir = tmppath("faults-matrix-ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    const NRANKS: usize = 4;
    const VICTIM: usize = 1;
    for entry in usecases::REGISTRY {
        for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
            let oracle = value_map(
                Job::new((entry.make)(), small_config(p.clone()))
                    .unwrap()
                    .run(backend, NRANKS, CostModel::default())
                    .unwrap()
                    .result,
            );
            for phase in ["map", "reduce"] {
                let ctx = format!("{} {} kill@{phase}", entry.name, backend.name());
                let cfg = JobConfig {
                    checkpoints: true,
                    checkpoint_dir: dir.clone(),
                    faults: Some(
                        format!("kill:rank={VICTIM}@phase={phase}").parse().unwrap(),
                    ),
                    ..small_config(p.clone())
                };
                let out = Job::new((entry.make)(), cfg)
                    .unwrap()
                    .run(backend, NRANKS, CostModel::default())
                    .unwrap();
                let report = &out.report;
                assert_eq!(report.nranks, NRANKS - 1, "{ctx}: survivors");
                assert_eq!(value_map(out.result), oracle, "{ctx}: result differs");
                let rec = report
                    .recovery
                    .as_ref()
                    .unwrap_or_else(|| panic!("{ctx}: no recovery breakdown"));
                assert_eq!(rec.dead_rank, VICTIM, "{ctx}");
                assert_eq!(rec.phase, phase, "{ctx}");
                assert_eq!(rec.orig_nranks, NRANKS, "{ctx}");
                assert!(rec.total_ns() > 0, "{ctx}: recovery cost must be nonzero");
                assert!(rec.replan_ns > 0, "{ctx}: replan charged on every survivor");
                assert!(rec.replayed_tasks > 0, "{ctx}: checkpoints must replay tasks");
                assert!(rec.replayed_bytes > 0, "{ctx}");
                // Span-sum consistency: each recovery component equals
                // the wait time attributed to its cause, and the whole
                // breakdown is contained in the ranks' wait_ns.
                let cause_ns = |c: WaitCause| -> u64 {
                    report
                        .spans
                        .iter()
                        .flatten()
                        .filter(|s| s.op == op::WAIT && s.cause == Some(c))
                        .map(|s| s.dur_ns())
                        .sum()
                };
                assert_eq!(rec.detect_ns, cause_ns(WaitCause::Detect), "{ctx}");
                assert_eq!(rec.replay_ns, cause_ns(WaitCause::Replay), "{ctx}");
                assert_eq!(rec.replan_ns, cause_ns(WaitCause::Replan), "{ctx}");
                let total_wait: u64 =
                    report.breakdowns.iter().map(|b| b.wait_ns).sum();
                assert!(
                    rec.total_ns() <= total_wait,
                    "{ctx}: recovery {} exceeds attributed wait {total_wait}",
                    rec.total_ns()
                );
                assert!(report.summary().contains("recovery=dead:"), "{ctx}");
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&p).ok();
}

#[test]
fn kill_recovery_without_checkpoints_recomputes_everything() {
    // Degraded mode must not depend on checkpoints: with none to replay
    // the survivors recompute every task from the input and still match
    // the oracle exactly.
    let p = corpus("faults-nockpt", 60_000, 42);
    let oracle = oracle_wordcount(&p);
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        for phase in ["map", "reduce"] {
            let ctx = format!("{} kill@{phase}", backend.name());
            let cfg = JobConfig {
                faults: Some(format!("kill:rank=2@phase={phase}").parse().unwrap()),
                ..small_config(p.clone())
            };
            let out = Job::new(Arc::new(WordCount), cfg)
                .unwrap()
                .run(backend, 4, CostModel::default())
                .unwrap();
            assert_eq!(out.report.nranks, 3, "{ctx}");
            assert_eq!(counts_map(out.result), oracle, "{ctx}");
            let rec = out.report.recovery.as_ref().unwrap();
            assert_eq!(rec.replayed_tasks, 0, "{ctx}: nothing to replay");
            assert_eq!(rec.replay_ns, 0, "{ctx}");
            assert!(rec.recomputed_tasks > 0, "{ctx}");
            assert!(rec.total_ns() > 0, "{ctx}: detect/replan still charged");
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn torn_checkpoint_write_still_recovers_from_the_valid_prefix() {
    // A crash mid-write leaves a truncated final frame; recovery must
    // fall back to the longest valid prefix and recompute the rest.
    let p = corpus("faults-torn", 60_000, 43);
    let dir = tmppath("faults-torn-ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let oracle = oracle_wordcount(&p);
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let cfg = JobConfig {
            checkpoints: true,
            checkpoint_dir: dir.clone(),
            faults: Some("kill:rank=1@phase=map,torn:rank=1".parse().unwrap()),
            ..small_config(p.clone())
        };
        let out = Job::new(Arc::new(WordCount), cfg)
            .unwrap()
            .run(backend, 4, CostModel::default())
            .unwrap();
        assert_eq!(out.report.nranks, 3, "{}", backend.name());
        assert_eq!(counts_map(out.result), oracle, "{}", backend.name());
        assert!(out.report.recovery.is_some(), "{}", backend.name());
    }
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&p).ok();
}

#[test]
fn slow_fault_stretches_the_victim_without_triggering_recovery() {
    let p = corpus("faults-slow", 150_000, 44);
    let oracle = oracle_wordcount(&p);
    let base = Job::new(Arc::new(WordCount), small_config(p.clone()))
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();
    let cfg = JobConfig {
        faults: Some("slow:rank=1@factor=4.0".parse().unwrap()),
        ..small_config(p.clone())
    };
    let slow = Job::new(Arc::new(WordCount), cfg)
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();
    assert_eq!(counts_map(slow.result), oracle);
    assert_eq!(slow.report.nranks, 4, "nobody died: full world");
    assert!(slow.report.recovery.is_none(), "slowdown is not a loss");
    assert!(
        slow.report.elapsed_ns > base.report.elapsed_ns,
        "a 4x straggler must stretch the makespan: {} !> {}",
        slow.report.elapsed_ns,
        base.report.elapsed_ns
    );
    std::fs::remove_file(&p).ok();
}

#[test]
fn pipelines_reject_armed_fault_plans() {
    let p = corpus("faults-pipe", 30_000, 45);
    let base = JobConfig {
        faults: Some("kill:rank=1@phase=map".parse().unwrap()),
        ..small_config(p.clone())
    };
    let plan = plans::tfidf_plan(p.clone(), BackendKind::OneSided);
    let err = Pipeline::new(plan, 4, CostModel::default(), base).unwrap_err();
    match err {
        Error::Config(msg) => {
            assert!(msg.contains("fault injection"), "unexpected message {msg:?}")
        }
        other => panic!("expected Error::Config, got {other}"),
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn pipeline_trace_merges_stages_with_spill_spans() {
    use mr1s::metrics::tracer::op;
    let p = corpus("trace-pipe", 150_000, 17);
    let base = JobConfig {
        input: p.clone(),
        task_size: 16 << 10,
        win_size: 16 << 10,
        chunk_size: 4 << 10,
        ..Default::default()
    };
    let plan = plans::by_name("tfidf", p.clone(), BackendKind::OneSided).unwrap();
    let pipe = Pipeline::new(plan, 4, CostModel::default(), base).unwrap();
    let out = pipe.run().unwrap();
    // Later stages tag their spans with their stage index.
    for (i, stage) in out.stages.iter().enumerate() {
        for spans in &stage.report.spans {
            assert!(spans.iter().all(|s| s.stage == i as u32), "stage {i} span tags");
        }
        if i > 0 {
            assert!(!stage.spill_spans.is_empty(), "stage {i} input was spilled");
            assert!(stage.spill_spans.iter().all(|s| s.op == op::SPILL_WRITE));
        }
    }
    let merged = out.merged_spans();
    assert_eq!(merged.len(), 4);
    let total_spill: usize = out.stages.iter().map(|s| s.spill_spans.len()).sum();
    assert!(total_spill > 0);
    assert_eq!(
        merged.iter().flatten().filter(|s| s.op == op::SPILL_WRITE).count(),
        total_spill,
    );
    std::fs::remove_dir_all(pipe.workdir()).ok();
    std::fs::remove_file(&p).ok();
}

// ---- live telemetry & straggler detection (DESIGN.md §11) ----------------

#[test]
fn secondary_sort_matches_oracle_on_both_backends() {
    let p = corpus("secsort", 80_000, 50);
    // Independent oracle: token -> sorted distinct lengths of the lines
    // containing it.
    let data = std::fs::read(&p).unwrap();
    let mut want: HashMap<Vec<u8>, BTreeSet<u32>> = HashMap::new();
    for line in data.split(|&b| b == b'\n') {
        for tok in WordCount::tokens(line) {
            want.entry(tok).or_default().insert(line.len() as u32);
        }
    }
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let out = Job::new(Arc::new(SecondarySort), small_config(p.clone()))
            .unwrap()
            .run(backend, 4, CostModel::default())
            .unwrap();
        assert_eq!(out.result.len(), want.len(), "{}", backend.name());
        for (key, value) in out.result {
            let got = SecondarySort::decode_keys(value.as_bytes().unwrap());
            let exp: Vec<u32> = want[&key].iter().copied().collect();
            assert_eq!(got, exp, "secondary keys of {:?}", String::from_utf8_lossy(&key));
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn telemetry_series_cover_every_rank_without_worker_side_spans() {
    // The plane is on by default (sample_every = 250us): both backends
    // must produce a non-empty, time-ordered, counter-monotonic series
    // per rank — and on MR-1S only the monitor (rank 0) may record
    // telemetry spans, because workers publish with free local stores.
    use mr1s::metrics::tracer::op;
    use mr1s::metrics::HealthKind;
    let p = corpus("telem-basic", 150_000, 51);
    let oracle = oracle_wordcount(&p);
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let out = Job::new(Arc::new(WordCount), small_config(p.clone()))
            .unwrap()
            .run(backend, 4, CostModel::default())
            .unwrap();
        let name = backend.name();
        assert_eq!(counts_map(out.result), oracle, "{name}");
        assert_eq!(out.report.telemetry.len(), 4, "{name}: one series per rank");
        for (rank, series) in out.report.telemetry.iter().enumerate() {
            assert!(!series.is_empty(), "{name}: rank {rank} has no samples");
            for w in series.windows(2) {
                assert!(w[0].vt <= w[1].vt, "{name}: rank {rank} samples out of order");
                assert!(
                    w[0].block.tasks_done <= w[1].block.tasks_done
                        && w[0].block.bytes_mapped <= w[1].block.bytes_mapped
                        && w[0].block.heartbeat_vt <= w[1].block.heartbeat_vt,
                    "{name}: rank {rank} counters regressed"
                );
            }
            let last = series.last().unwrap().block;
            assert!(last.heartbeat_vt > 0, "{name}: rank {rank} never heartbeat");
            assert!(last.tasks_done > 0, "{name}: rank {rank} reported no progress");
        }
        // Telemetry must be invisible to workers: sampling spans live on
        // the monitor's rank only (MR-1S reads one-sidedly from rank 0;
        // MR-2S folds a collective round, recording no sampling spans).
        for (rank, spans) in out.report.spans.iter().enumerate().skip(1) {
            assert!(
                !spans.iter().any(|s| s.op == op::TELEMETRY_SAMPLE || s.op == op::HEALTH),
                "{name}: rank {rank} recorded telemetry spans"
            );
        }
        if backend == BackendKind::OneSided {
            assert!(
                out.report.spans[0].iter().any(|s| s.op == op::TELEMETRY_SAMPLE),
                "MR-1S monitor must record its sampling reads"
            );
        }
        // A healthy uniform run escalates nobody: transient SlowProgress
        // on a short tail is tolerated, hard flags are not.
        assert!(
            !out.report.health.iter().any(|e| e.kind == HealthKind::StragglerDetected
                || e.kind == HealthKind::HeartbeatStale),
            "{name}: spurious {:?}",
            out.report.health
        );
        // The monitor adds no waiting anywhere: the PR 6 invariant that
        // WAIT spans reproduce wait_ns must survive telemetry-on runs.
        for (spans, b) in out.report.spans.iter().zip(&out.report.breakdowns) {
            let wait_sum: u64 =
                spans.iter().filter(|s| s.op == op::WAIT).map(|s| s.dur_ns()).sum();
            assert_eq!(wait_sum, b.wait_ns, "{name}: wait spans != wait_ns");
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn slow_fault_is_flagged_as_a_straggler_for_exactly_the_victim() {
    use mr1s::metrics::tracer::op;
    use mr1s::metrics::HealthKind;
    let p = corpus("telem-slow", 300_000, 52);
    let oracle = oracle_wordcount(&p);
    let cfg = JobConfig {
        sample_every: 10_000, // dense cadence: many observations per task
        faults: Some("slow:rank=1@factor=6.0".parse().unwrap()),
        ..small_config(p.clone())
    };
    let out = Job::new(Arc::new(WordCount), cfg)
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();
    assert_eq!(counts_map(out.result), oracle);
    let hard: Vec<_> = out
        .report
        .health
        .iter()
        .filter(|e| e.kind == HealthKind::StragglerDetected)
        .collect();
    assert!(!hard.is_empty(), "a 6x straggler must escalate to straggler-detected");
    assert!(hard.iter().all(|e| e.rank == 1), "only rank 1 is slow: {hard:?}");
    // Health events surface in the human summary and as tracer spans on
    // the monitor's rank.
    let summary = out.report.summary();
    assert!(summary.contains("health="), "summary lacks health: {summary}");
    assert!(summary.contains("straggler-detected:1"), "summary: {summary}");
    assert!(
        out.report.spans[0].iter().any(|s| s.op == op::HEALTH && s.peer == Some(1)),
        "health events must be visible in the trace"
    );
    std::fs::remove_file(&p).ok();
}

#[test]
fn straggler_hint_steers_stealing_toward_the_flagged_rank() {
    use mr1s::metrics::tracer::op;
    use mr1s::metrics::HealthKind;
    let p = corpus("telem-steal", 300_000, 53);
    let oracle = oracle_wordcount(&p);
    let cfg = JobConfig {
        job_stealing: true,
        sample_every: 10_000,
        faults: Some("slow:rank=1@factor=6.0".parse().unwrap()),
        ..small_config(p.clone())
    };
    let out = Job::new(Arc::new(WordCount), cfg)
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();
    assert_eq!(counts_map(out.result), oracle, "stealing + slow fault stays exact");
    let flag_vt = out
        .report
        .health
        .iter()
        .filter(|e| e.kind == HealthKind::StragglerDetected && e.rank == 1)
        .map(|e| e.vt)
        .min()
        .expect("the 6x straggler is detected");
    let claims: Vec<_> = out
        .report
        .spans
        .iter()
        .flatten()
        .filter(|s| s.op == op::STEAL_CLAIM)
        .collect();
    assert!(!claims.is_empty(), "fast ranks must steal from the straggler");
    assert!(
        claims.iter().any(|s| s.peer == Some(1)),
        "somebody must relieve the flagged rank: {claims:?}"
    );
    // The hint takes effect from the moment the detector fires: the
    // first claim issued at-or-after the flag targets the flagged rank.
    if let Some(first) = claims.iter().filter(|s| s.t0 >= flag_vt).min_by_key(|s| s.t0) {
        assert_eq!(
            first.peer,
            Some(1),
            "post-flag steals must prefer the straggler (flag at {flag_vt})"
        );
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn kill_runs_surface_heartbeat_stale_for_the_dead_rank() {
    use mr1s::metrics::tracer::op;
    use mr1s::metrics::HealthKind;
    let p = corpus("telem-kill", 60_000, 54);
    let dir = tmppath("telem-kill-ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    const VICTIM: usize = 2;
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        let cfg = JobConfig {
            checkpoints: true,
            checkpoint_dir: dir.clone(),
            faults: Some(format!("kill:rank={VICTIM}@phase=map").parse().unwrap()),
            ..small_config(p.clone())
        };
        let out = Job::new(Arc::new(WordCount), cfg)
            .unwrap()
            .run(backend, 4, CostModel::default())
            .unwrap();
        let name = backend.name();
        assert!(out.report.recovery.is_some(), "{name}");
        let stale: Vec<_> = out
            .report
            .health
            .iter()
            .filter(|e| e.kind == HealthKind::HeartbeatStale)
            .collect();
        assert_eq!(stale.len(), 1, "{name}: exactly one stale heartbeat: {stale:?}");
        assert_eq!(stale[0].rank, VICTIM, "{name}: the dead rank goes stale");
        let summary = out.report.summary();
        assert!(
            summary.contains(&format!("heartbeat-stale:{VICTIM}")),
            "{name}: summary lacks the stale heartbeat: {summary}"
        );
        assert!(
            out.report.spans[0]
                .iter()
                .any(|s| s.op == op::HEALTH && s.peer == Some(VICTIM)),
            "{name}: stale heartbeat must be visible in the trace"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&p).ok();
}

// ---- run ledger + differential attribution (DESIGN.md §12) --------------

#[test]
fn ledger_round_trips_and_diff_is_exact_across_routes() {
    // For every route × backend: build records from two runs whose
    // configs differ (task size), persist A to disk, load it back
    // losslessly, and check the differ's exactness invariant — the
    // components sum to the elapsed delta to the nanosecond, and a
    // self-diff attributes nothing.
    use mr1s::metrics::diff::diff_ledgers;
    use mr1s::metrics::ledger::{RunLedger, RunRecord};
    let p = corpus("ledger-routes", 120_000, 51);
    for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
        for route in all_routes() {
            let run = |task_size: usize| {
                let cfg = JobConfig { route, task_size, ..small_config(p.clone()) };
                Job::new(Arc::new(WordCount), cfg)
                    .unwrap()
                    .run(backend, 4, CostModel::default())
                    .unwrap()
            };
            let ctx = format!("{} {route:?}", backend.name());
            let (out_a, out_b) = (run(16 << 10), run(32 << 10));
            let route_label = route.label();
            let mut a = RunLedger::new("it", "config=a");
            a.push(RunRecord::from_report("job", "word-count", &route_label, &out_a.report));
            let mut b = RunLedger::new("it", "config=b");
            b.push(RunRecord::from_report("job", "word-count", &route_label, &out_b.report));

            // Driver-built records tile the makespan (zero untracked)
            // and decompose each rank exactly.
            for rec in a.runs.iter().chain(&b.runs) {
                assert_eq!(rec.untracked_ns(), 0, "{ctx}: crit path must tile the makespan");
                for (i, rank) in rec.ranks.iter().enumerate() {
                    assert_eq!(
                        rank.components_total_ns(),
                        rank.elapsed_ns,
                        "{ctx}: rank {i} decomposition inexact"
                    );
                }
                let fp = rec.route_fingerprint.as_ref().expect("fingerprint recorded");
                assert_eq!(fp.nranks, 4, "{ctx}");
            }

            // Disk round trip is lossless.
            let path = tmppath(&format!(
                "ledger-{}-{}",
                backend.name(),
                route_label.replace([':', '='], "-")
            ));
            a.write_to(&path).unwrap();
            let back = RunLedger::load(&path).unwrap();
            assert_eq!(a, back, "{ctx}: ledger JSON round trip must be lossless");
            std::fs::remove_file(&path).ok();

            // Exactness invariant on the real pair, both directions.
            for (x, y) in [(&a, &b), (&b, &a)] {
                let d = diff_ledgers(x, y);
                assert_eq!(d.pairs.len(), 1, "{ctx}: runs must align");
                let pair = &d.pairs[0];
                assert_eq!(pair.residual_ns(), 0, "{ctx}: nonzero residual");
                assert_eq!(
                    pair.components_delta_ns(),
                    pair.delta_elapsed_ns(),
                    "{ctx}: components must sum to the elapsed delta"
                );
            }

            // Self-diff: zero everywhere, same fingerprint, no causes.
            let d = diff_ledgers(&a, &a);
            let pair = &d.pairs[0];
            assert_eq!(pair.delta_elapsed_ns(), 0, "{ctx}");
            assert!(pair.components.iter().all(|c| c.delta_ns() == 0), "{ctx}");
            assert!(
                matches!(pair.route, mr1s::metrics::diff::RouteDivergence::Same(_)),
                "{ctx}: identical run must fingerprint as the same plan"
            );
            assert!(d.top_causes(10).is_empty(), "{ctx}");
        }
    }
    std::fs::remove_file(&p).ok();
}

#[test]
fn kill_run_ledger_carries_recovery_attribution() {
    // A recovered run's ledger record must carry the recovery section
    // and route the detect/replay/replan costs through the per-cause
    // wait decomposition, and the shared bench funnel must emit the
    // `<tag>_recovery_*` samples fig10's JSON is built from.
    use mr1s::metrics::ledger::RunRecord;
    let p = corpus("ledger-kill", 60_000, 52);
    let dir = tmppath("ledger-kill-ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = JobConfig {
        checkpoints: true,
        checkpoint_dir: dir.clone(),
        faults: Some("kill:rank=1@phase=map".parse().unwrap()),
        ..small_config(p.clone())
    };
    let out = Job::new(Arc::new(WordCount), cfg)
        .unwrap()
        .run(BackendKind::OneSided, 4, CostModel::default())
        .unwrap();
    let rec = RunRecord::from_report("kill", "word-count", "modulo", &out.report);

    let ledger_rec = rec.recovery.as_ref().expect("recovery section present");
    let report_rec = out.report.recovery.as_ref().unwrap();
    assert_eq!(ledger_rec.phase, "map");
    assert_eq!(ledger_rec.orig_nranks, 4);
    assert_eq!(ledger_rec.total_ns(), report_rec.total_ns());
    assert!(ledger_rec.total_ns() > 0, "recovery must cost something");
    // The same costs appear as attributed waits in the rank ledgers.
    let wait = |cause: &str| -> u64 {
        rec.ranks.iter().map(|r| r.wait_ns.get(cause).copied().unwrap_or(0)).sum()
    };
    assert_eq!(wait("detect"), report_rec.detect_ns, "detect wait != recovery detect");
    assert_eq!(wait("replay"), report_rec.replay_ns, "replay wait != recovery replay");
    assert_eq!(wait("replan"), report_rec.replan_ns, "replan wait != recovery replan");
    assert_eq!(rec.key.nranks, 3, "ledger keys the degraded world");

    let samples = mr1s::bench::job_samples("kill", &out.report);
    let find = |name: &str| {
        samples
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing sample {name}"))
            .mean
    };
    assert_eq!(find("kill_recovery_total_ns"), report_rec.total_ns() as f64);
    assert_eq!(find("kill_recovery_replayed_tasks"), report_rec.replayed_tasks as f64);
    assert_eq!(find("kill_recovery_replayed_bytes"), report_rec.replayed_bytes as f64);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_file(&p).ok();
}
