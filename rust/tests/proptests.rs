//! Property-based tests over the coordinator's core invariants (routing,
//! batching, encoding, run algebra, record-boundary handling, and whole
//! mini-jobs), via the in-tree PropRunner (proptest is unavailable
//! offline).

use std::collections::HashMap;
use std::sync::Arc;

use mr1s::mapreduce::bucket::{KeyTable, OwnedRecord, SortedRun};
use mr1s::mapreduce::job::{
    read_len, read_start, split_tasks, split_tasks_records, task_records,
};
use mr1s::mapreduce::kv::{self, ConcatOps, Record, SumOps, Value, ValueKind};
use mr1s::mapreduce::{BackendKind, Job, JobConfig};
use mr1s::shuffle::{plan_route, Sketch};
use mr1s::sim::{CostModel, StorageModel};
use mr1s::storage::spill::{index_path, SpillFile, SpillWriter};
use mr1s::testing::PropRunner;
use mr1s::usecases::WordCount;
use mr1s::workload::SplitMix64;

fn rand_key(rng: &mut SplitMix64) -> Vec<u8> {
    let len = rng.below(40) as usize; // includes empty and > HASH_WIDTH
    (0..len).map(|_| rng.below(256) as u8).collect()
}

fn rand_value(rng: &mut SplitMix64) -> Vec<u8> {
    let len = rng.below(24) as usize; // includes empty and non-8-byte
    (0..len).map(|_| rng.below(256) as u8).collect()
}

#[test]
fn prop_kv_roundtrip_any_records() {
    PropRunner::new(200).check(
        "kv roundtrip",
        |rng| {
            let n = 1 + rng.below(64) as usize;
            (0..n)
                .map(|_| (rand_key(rng), rng.next_u64(), rand_value(rng)))
                .collect::<Vec<_>>()
        },
        |recs| {
            let mut buf = Vec::new();
            for (key, hash, value) in recs {
                Record { hash: *hash, key, value }.encode_into(&mut buf);
            }
            let decoded = kv::decode_all(&buf).map_err(|e| e.to_string())?;
            if decoded.len() != recs.len() {
                return Err(format!("{} != {}", decoded.len(), recs.len()));
            }
            for (d, (key, hash, value)) in decoded.iter().zip(recs) {
                if d.key != key.as_slice() || d.hash != *hash || d.value != value.as_slice() {
                    return Err("record mismatch".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_owner_routing_is_total_and_stable() {
    PropRunner::new(300).check(
        "owner routing",
        |rng| (rand_key(rng), 1 + rng.below(64) as usize),
        |(key, nranks)| {
            let h = kv::hash_key(key);
            let owner = kv::owner_of(h, *nranks);
            if owner >= *nranks {
                return Err(format!("owner {owner} out of range {nranks}"));
            }
            if owner != kv::owner_of(h, *nranks) {
                return Err("owner not deterministic".into());
            }
            // Consistent with the kernel's bucket contract.
            if owner != kv::bucket_of(h) % *nranks {
                return Err("owner != bucket % nranks".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_keytable_preserves_total_count() {
    PropRunner::new(100).check(
        "keytable count conservation",
        |rng| {
            let n = 1 + rng.below(500) as usize;
            // Small key space to force merging.
            (0..n)
                .map(|_| (rng.below(20), rng.below(100) + 1))
                .collect::<Vec<(u64, u64)>>()
        },
        |pairs| {
            let mut table = KeyTable::new();
            for (k, c) in pairs {
                let key = k.to_le_bytes();
                table.merge(kv::hash_key(&key), &key, &c.to_le_bytes(), &SumOps);
            }
            let want: u64 = pairs.iter().map(|(_, c)| *c).sum();
            let got: u64 = table
                .drain_records()
                .iter()
                .map(|r| r.value.as_u64().unwrap())
                .sum();
            (got == want).then_some(()).ok_or(format!("{got} != {want}"))
        },
    );
}

#[test]
fn prop_keytable_variable_values_concatenate_all_bytes() {
    // The variable tier must conserve payload bytes through local
    // reduce + drain, independent of merge order.
    PropRunner::new(60).check(
        "keytable variable-value conservation",
        |rng| {
            let n = 1 + rng.below(200) as usize;
            (0..n)
                .map(|_| (rng.below(10), rand_value(rng)))
                .collect::<Vec<(u64, Vec<u8>)>>()
        },
        |pairs| {
            let mut table = KeyTable::new();
            for (k, v) in pairs {
                let key = k.to_le_bytes();
                table.merge(kv::hash_key(&key), &key, v, &ConcatOps);
            }
            let want: usize = pairs.iter().map(|(_, v)| v.len()).sum();
            let got: usize = table
                .drain_records()
                .iter()
                .map(|r| r.value.as_bytes().unwrap().len())
                .sum();
            (got == want).then_some(()).ok_or(format!("{got} != {want} payload bytes"))
        },
    );
}

#[test]
fn prop_keytable_partition_is_exact() {
    PropRunner::new(100).check(
        "drain_by_owner partitions",
        |rng| {
            let n = 1 + rng.below(200) as usize;
            let nranks = 1 + rng.below(16) as usize;
            ((0..n).map(|_| rand_key(rng)).collect::<Vec<_>>(), nranks)
        },
        |(keys, nranks)| {
            let mut table = KeyTable::new();
            for k in keys {
                table.merge(kv::hash_key(k), k, &1u64.to_le_bytes(), &SumOps);
            }
            let unique = table.len();
            let parts = table.drain_by_owner(*nranks).map_err(|e| e.to_string())?;
            let mut total = 0usize;
            for (r, buf) in parts.iter().enumerate() {
                for rec in kv::RecordIter::new(buf) {
                    let rec = rec.map_err(|e| e.to_string())?;
                    if kv::owner_of(rec.hash, *nranks) != r {
                        return Err(format!("record routed to wrong rank {r}"));
                    }
                    total += 1;
                }
            }
            (total == unique).then_some(()).ok_or(format!("{total} != {unique}"))
        },
    );
}

#[test]
fn prop_planned_route_partition_is_exact() {
    // Any sketch-derived plan must stay a total, in-range routing: every
    // record lands on exactly one rank, split keys land on the rank the
    // route assigns *this source*, and nothing is lost or duplicated.
    PropRunner::new(60).check(
        "drain_routed partitions under a plan",
        |rng| {
            let n = 1 + rng.below(300) as usize;
            let nranks = 1 + rng.below(12) as usize;
            let split = 1 + rng.below(6) as usize;
            let source = rng.below(12) as usize % nranks;
            // Skewed draws so heavy hitters exist and sometimes split.
            let keys: Vec<u64> =
                (0..n).map(|_| if rng.below(3) == 0 { 7 } else { rng.below(5000) }).collect();
            (keys, nranks, split, source)
        },
        |(keys, nranks, split, source)| {
            let mut table = KeyTable::new();
            for k in keys {
                let key = k.to_le_bytes();
                table.merge(kv::hash_key(&key), &key, &1u64.to_le_bytes(), &SumOps);
            }
            let unique = table.len();
            let mut sketch = Sketch::new();
            table.for_each_size(&mut |h, len| sketch.observe(h, len as u64));
            let route = plan_route(&sketch, *nranks, *split);
            let parts = table.drain_routed(&route, *source).map_err(|e| e.to_string())?;
            if parts.len() != *nranks {
                return Err(format!("{} part buffers for {nranks} ranks", parts.len()));
            }
            let mut total = 0usize;
            for (r, buf) in parts.iter().enumerate() {
                for rec in kv::RecordIter::new(buf) {
                    let rec = rec.map_err(|e| e.to_string())?;
                    if route.owner(rec.hash, *source) != r {
                        return Err(format!("record routed to wrong rank {r}"));
                    }
                    total += 1;
                }
            }
            (total == unique).then_some(()).ok_or(format!("{total} != {unique}"))
        },
    );
}

#[test]
fn prop_sorted_run_invariants_and_merge_algebra() {
    PropRunner::new(150).check(
        "sorted-run build+merge",
        |rng| {
            let n = rng.below(300) as usize;
            let m = rng.below(300) as usize;
            let mk = |rng: &mut SplitMix64, n: usize| {
                (0..n)
                    .map(|_| {
                        let k = rng.below(50).to_le_bytes().to_vec(); // collisions likely
                        (k, rng.below(100))
                    })
                    .collect::<Vec<_>>()
            };
            (mk(rng, n), mk(rng, m))
        },
        |(a, b)| {
            let to_records = |xs: &[(Vec<u8>, u64)]| {
                xs.iter()
                    .map(|(k, c)| OwnedRecord {
                        hash: kv::hash_key(k),
                        key: k.as_slice().into(),
                        value: Value::U64(*c),
                    })
                    .collect::<Vec<_>>()
            };
            let ra = SortedRun::build_scalar(to_records(a), &SumOps);
            let rb = SortedRun::build_scalar(to_records(b), &SumOps);
            if !ra.check_invariants() || !rb.check_invariants() {
                return Err("build violated run invariants".into());
            }
            let merged = ra.merge(rb, &SumOps);
            if !merged.check_invariants() {
                return Err("merge violated run invariants".into());
            }
            // Count conservation through build + merge.
            let want: u64 = a.iter().chain(b).map(|(_, c)| *c).sum();
            let got: u64 = merged
                .records()
                .iter()
                .map(|r| r.value.as_u64().unwrap())
                .sum();
            (got == want).then_some(()).ok_or(format!("{got} != {want}"))
        },
    );
}

#[test]
fn prop_run_encode_decode_roundtrip() {
    PropRunner::new(100).check(
        "run codec",
        |rng| {
            (0..rng.below(200) as usize)
                .map(|_| (rand_key(rng), rng.below(1000)))
                .collect::<Vec<_>>()
        },
        |xs| {
            let records = xs
                .iter()
                .map(|(k, c)| OwnedRecord {
                    hash: kv::hash_key(k),
                    key: k.as_slice().into(),
                    value: Value::U64(*c),
                })
                .collect();
            let run = SortedRun::build_scalar(records, &SumOps);
            let encoded = run.encode().map_err(|e| e.to_string())?;
            let rt =
                SortedRun::decode(&encoded, ValueKind::InlineU64).map_err(|e| e.to_string())?;
            (rt.records() == run.records()).then_some(()).ok_or("roundtrip mismatch".into())
        },
    );
}

#[test]
fn prop_task_records_partition_any_text() {
    PropRunner::new(60).check(
        "record boundaries",
        |rng| {
            let len = rng.below(4000) as usize;
            let mut text = Vec::with_capacity(len);
            for _ in 0..len {
                // Bias toward printable with ~8% newlines.
                let b = if rng.below(12) == 0 { b'\n' } else { b'a' + rng.below(26) as u8 };
                text.push(b);
            }
            let task_size = 1 + rng.below(500) as usize;
            (text, task_size)
        },
        |(text, task_size)| {
            let tasks = split_tasks(text.len() as u64, *task_size);
            let mut seen = Vec::new();
            for t in &tasks {
                let rs = read_start(t) as usize;
                let re = (rs + read_len(t)).min(text.len());
                let data = &text[rs..re];
                let range = task_records(t, data);
                seen.extend_from_slice(&data[range]);
            }
            (seen == *text)
                .then_some(())
                .ok_or(format!("partition lost bytes: {} != {}", seen.len(), text.len()))
        },
    );
}

#[test]
fn prop_mini_jobs_match_oracle_both_backends() {
    // Whole-job property: random tiny corpora, random task sizes, random
    // rank counts — exact counts from both backends.
    let tmp = std::env::temp_dir().join(format!("mr1s-prop-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let mut case_no = 0usize;
    PropRunner::new(8).check(
        "mini job e2e",
        |rng| {
            let words = ["wiki", "data", "map", "reduce", "one", "sided"];
            let lines = 20 + rng.below(200) as usize;
            let mut text = String::new();
            for _ in 0..lines {
                let n = 1 + rng.below(8) as usize;
                for _ in 0..n {
                    text.push_str(words[rng.below(words.len() as u64) as usize]);
                    text.push(' ');
                }
                text.push('\n');
            }
            let task_size = 64 + rng.below(2000) as usize;
            let nranks = 1 + rng.below(6) as usize;
            (text, task_size, nranks)
        },
        |(text, task_size, nranks)| {
            case_no += 1;
            let path = tmp.join(format!("case-{case_no}.txt"));
            std::fs::write(&path, text).map_err(|e| e.to_string())?;
            let mut oracle: HashMap<Vec<u8>, u64> = HashMap::new();
            for line in text.as_bytes().split(|&b| b == b'\n') {
                for tok in WordCount::tokens(line) {
                    *oracle.entry(tok).or_insert(0) += 1;
                }
            }
            for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
                let cfg = JobConfig {
                    input: path.clone(),
                    task_size: *task_size,
                    win_size: 8 << 10,
                    chunk_size: 2 << 10,
                    use_kernel: false,
                    ..Default::default()
                };
                let out = Job::new(Arc::new(WordCount), cfg)
                    .map_err(|e| e.to_string())?
                    .run(backend, *nranks, CostModel::default())
                    .map_err(|e| e.to_string())?;
                let got: HashMap<Vec<u8>, u64> = out
                    .result
                    .into_iter()
                    .map(|(k, v)| (k, v.as_u64().unwrap()))
                    .collect();
                if got != oracle {
                    return Err(format!(
                        "{} disagrees with oracle ({} vs {} keys)",
                        backend.name(),
                        got.len(),
                        oracle.len()
                    ));
                }
            }
            std::fs::remove_file(&path).ok();
            Ok(())
        },
    );
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn prop_hash_colliding_keys_stay_distinct_end_to_end() {
    // Two distinct keys sharing the full 24-byte HASH_WIDTH prefix hash
    // identically (`hash_key` truncates), so they collide in every
    // hash-keyed structure — the staging table, the wire buckets, the
    // sorted runs (`bucket::Chain::Many` across the wire).  A full job
    // must still count them separately on both backends.
    let tmp = std::env::temp_dir().join(format!("mr1s-prop-coll-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let mut case_no = 0usize;
    PropRunner::new(6).check(
        "hash-collision e2e",
        |rng| {
            // A random 24-byte lowercase prefix + 1-byte distinct suffixes.
            let prefix: String =
                (0..kv::HASH_WIDTH).map(|_| (b'a' + rng.below(26) as u8) as char).collect();
            let ca = (b'a' + rng.below(13) as u8) as char;
            let cb = (b'n' + rng.below(13) as u8) as char; // disjoint range: always distinct
            let na = 1 + rng.below(40) as usize;
            let nb = 1 + rng.below(40) as usize;
            let filler_lines = rng.below(30) as usize;
            let task_size = 64 + rng.below(800) as usize;
            let nranks = 1 + rng.below(5) as usize;
            (format!("{prefix}{ca}"), format!("{prefix}{cb}"), na, nb, filler_lines, task_size, nranks)
        },
        |(key_a, key_b, na, nb, filler_lines, task_size, nranks)| {
            let ha = kv::hash_key(key_a.as_bytes());
            let hb = kv::hash_key(key_b.as_bytes());
            if ha != hb {
                return Err("premise broken: prefix-sharing keys must collide".into());
            }
            if key_a == key_b {
                return Err("premise broken: keys must be distinct".into());
            }
            case_no += 1;
            let path = tmp.join(format!("case-{case_no}.txt"));
            let mut text = String::new();
            for i in 0..*na {
                text.push_str(key_a);
                text.push(if i % 3 == 0 { '\n' } else { ' ' });
            }
            for i in 0..*nb {
                text.push_str(key_b);
                text.push(if i % 2 == 0 { '\n' } else { ' ' });
            }
            for i in 0..*filler_lines {
                text.push_str(&format!("filler words number {i}\n"));
            }
            std::fs::write(&path, &text).map_err(|e| e.to_string())?;

            for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
                let cfg = JobConfig {
                    input: path.clone(),
                    task_size: *task_size,
                    win_size: 8 << 10,
                    chunk_size: 2 << 10,
                    use_kernel: false,
                    ..Default::default()
                };
                let out = Job::new(Arc::new(WordCount), cfg)
                    .map_err(|e| e.to_string())?
                    .run(backend, *nranks, CostModel::default())
                    .map_err(|e| e.to_string())?;
                let got: HashMap<Vec<u8>, u64> = out
                    .result
                    .into_iter()
                    .map(|(k, v)| (k, v.as_u64().unwrap()))
                    .collect();
                let ca = got.get(key_a.as_bytes()).copied();
                let cb = got.get(key_b.as_bytes()).copied();
                if ca != Some(*na as u64) || cb != Some(*nb as u64) {
                    return Err(format!(
                        "{}: colliding keys miscounted: {key_a}={ca:?} (want {na}), \
                         {key_b}={cb:?} (want {nb})",
                        backend.name()
                    ));
                }
            }
            std::fs::remove_file(&path).ok();
            Ok(())
        },
    );
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn prop_spill_roundtrip_both_tiers() {
    // A job output spilled through the storage layer and read back via
    // StripedFile must decode bit-exactly — for inline-u64 and variable
    // values, tagged or not — and the sidecar boundary index must both
    // match the records and survive a reopen.
    let tmp = std::env::temp_dir().join(format!("mr1s-prop-spill-{}", std::process::id()));
    std::fs::create_dir_all(&tmp).unwrap();
    let mut case_no = 0usize;
    PropRunner::new(40).check(
        "spill roundtrip",
        |rng| {
            let n = 1 + rng.below(60) as usize;
            let inline_tier = rng.below(2) == 0;
            let tag = (rng.below(2) == 0).then(|| rng.below(256) as u8);
            let records: Vec<(Vec<u8>, Value)> = (0..n)
                .map(|_| {
                    let key = rand_key(rng);
                    let value = if inline_tier {
                        Value::U64(rng.next_u64())
                    } else {
                        Value::Bytes(rand_value(rng))
                    };
                    (key, value)
                })
                .collect();
            (records, tag)
        },
        |(records, tag)| {
            case_no += 1;
            let path = tmp.join(format!("case-{case_no}.spill"));
            let mut writer = SpillWriter::create(&path).map_err(|e| e.to_string())?;
            writer
                .append_records(records, *tag, 0, &StorageModel::default())
                .map_err(|e| e.to_string())?;
            let spill = writer.finish().map_err(|e| e.to_string())?;

            let decoded = spill.decode_all().map_err(|e| e.to_string())?;
            if decoded.len() != records.len() {
                return Err(format!("{} records != {}", decoded.len(), records.len()));
            }
            for ((hash, key, value), (k, v)) in decoded.iter().zip(records) {
                if *hash != kv::hash_key(k) || key != k {
                    return Err("hash/key mismatch".into());
                }
                let mut want = Vec::new();
                if let Some(t) = tag {
                    want.push(*t);
                }
                v.write_into(&mut want);
                if *value != want {
                    return Err("value bytes mismatch".into());
                }
            }

            // Boundary index: one entry per record, strictly increasing,
            // starting at 0; task splitting tiles the file exactly.
            if spill.boundaries.len() != records.len() || spill.boundaries[0] != 0 {
                return Err("bad boundary count".into());
            }
            if !spill.boundaries.windows(2).all(|w| w[0] < w[1]) {
                return Err("boundaries not increasing".into());
            }
            let tasks = split_tasks_records(&spill.boundaries, spill.file.len(), 64);
            let covered: u64 = tasks.iter().map(|t| t.len as u64).sum();
            if covered != spill.file.len() {
                return Err(format!("tasks cover {covered} of {}", spill.file.len()));
            }

            let reopened = SpillFile::open(&path).map_err(|e| e.to_string())?;
            if reopened.boundaries != spill.boundaries {
                return Err("sidecar reopen disagrees".into());
            }
            std::fs::remove_file(&path).ok();
            std::fs::remove_file(index_path(&path)).ok();
            Ok(())
        },
    );
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn prop_checkpoint_truncation_any_byte_decodes_valid_prefix() {
    // A checkpoint stream torn at ANY byte offset must decode to exactly
    // the frames wholly before the cut — never garbage, never a partial
    // frame, and `valid_prefix` must report the byte length of that
    // decodable prefix.
    use mr1s::fault::{encode_frame, valid_prefix};
    PropRunner::new(150).check(
        "torn checkpoint decodes valid prefix",
        |rng| {
            let n = 1 + rng.below(10) as usize;
            let frames: Vec<(u32, Vec<u8>)> =
                (0..n).map(|i| (i as u32, rand_value(rng))).collect();
            let mut buf = Vec::new();
            let mut ends = Vec::new();
            for (id, payload) in &frames {
                encode_frame(&mut buf, *id, payload);
                ends.push(buf.len());
            }
            let cut = rng.below(buf.len() as u64 + 1) as usize;
            (frames, buf, ends, cut)
        },
        |(frames, buf, ends, cut)| {
            let (decoded, valid) = valid_prefix(&buf[..*cut]);
            let want = ends.iter().filter(|&&e| e <= *cut).count();
            if decoded.len() != want {
                return Err(format!(
                    "cut {cut}: {} frames decoded, want {want}",
                    decoded.len()
                ));
            }
            let want_valid = if want == 0 { 0 } else { ends[want - 1] };
            if valid != want_valid {
                return Err(format!("cut {cut}: {valid} valid bytes, want {want_valid}"));
            }
            for (d, (id, payload)) in decoded.iter().zip(frames) {
                if d.task_id != *id || d.payload != payload.as_slice() {
                    return Err(format!("frame {id} corrupted through truncation"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_replay_log_recovers_exactly_the_checkpointed_prefix_both_tiers() {
    // The recovery contract: after a crash tears the checkpoint stream
    // at an arbitrary byte, the replay log must hand back bit-exact
    // records for every task checkpointed before the tear, and nothing
    // (`None` → recompute) for the lost suffix — for inline-u64 values
    // and for variable values including ones past the u16 length escape.
    use mr1s::fault::{encode_frame, ReplayLog};
    PropRunner::new(60).check(
        "replay log prefix recovery",
        |rng| {
            let ntasks = 1 + rng.below(8) as usize;
            let inline_tier = rng.below(2) == 0;
            let tasks: Vec<Vec<(Vec<u8>, Value)>> = (0..ntasks)
                .map(|_| {
                    let nrecs = 1 + rng.below(12) as usize;
                    (0..nrecs)
                        .map(|_| {
                            let key = rand_key(rng);
                            let value = if inline_tier {
                                Value::U64(rng.next_u64())
                            } else if rng.below(16) == 0 {
                                // Past the u16 cap: exercises the u32
                                // extension-header escape on the wire.
                                let n = (u16::MAX as usize) + 1 + rng.below(512) as usize;
                                Value::Bytes(vec![rng.below(256) as u8; n])
                            } else {
                                Value::Bytes(rand_value(rng))
                            };
                            (key, value)
                        })
                        .collect()
                })
                .collect();
            let mut buf = Vec::new();
            let mut ends = Vec::new();
            for (id, records) in tasks.iter().enumerate() {
                let mut payload = Vec::new();
                for (key, value) in records {
                    OwnedRecord { hash: kv::hash_key(key), key: key.as_slice().into(), value: value.clone() }
                        .encode_into(&mut payload)
                        .expect("u32 escape covers test values");
                }
                encode_frame(&mut buf, id as u32, &payload);
                ends.push(buf.len());
            }
            let cut = rng.below(buf.len() as u64 + 1) as usize;
            (tasks, buf, ends, cut)
        },
        |(tasks, buf, ends, cut)| {
            let mut log = ReplayLog::default();
            log.ingest(&buf[..*cut]);
            for (id, records) in tasks.iter().enumerate() {
                let survived = ends[id] <= *cut;
                match log.task(id) {
                    None if survived => {
                        return Err(format!("task {id} checkpointed before the tear but lost"))
                    }
                    Some(_) if !survived => {
                        return Err(format!("task {id} lost in the tear but replayed"))
                    }
                    None => {} // lost suffix → recomputed, as required
                    Some(payload) => {
                        let decoded = kv::decode_all(payload).map_err(|e| e.to_string())?;
                        if decoded.len() != records.len() {
                            return Err(format!(
                                "task {id}: {} records replayed, want {}",
                                decoded.len(),
                                records.len()
                            ));
                        }
                        for (d, (key, value)) in decoded.iter().zip(records) {
                            let mut want = Vec::new();
                            value.write_into(&mut want);
                            if d.key != key.as_slice() || d.value != want {
                                return Err(format!("task {id}: replayed record differs"));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_telemetry_ring_keeps_the_latest_suffix() {
    // The monitor's per-rank ring buffer may drop history but never the
    // present: after any sequence of pushes, `latest()` is the last
    // sample pushed, and the retained window is exactly the newest
    // `min(pushed, cap)` samples in push order.
    use mr1s::metrics::{RingSeries, TelemetryBlock, TelemetrySample};
    PropRunner::new(300).check(
        "telemetry ring retention",
        |rng| {
            let cap = 1 + rng.below(40) as usize;
            let n = rng.below(200) as usize;
            let mut vt = 0u64;
            let samples: Vec<TelemetrySample> = (0..n)
                .map(|i| {
                    vt += 1 + rng.below(10_000);
                    TelemetrySample {
                        vt,
                        block: TelemetryBlock { tasks_done: i as u64, ..Default::default() },
                    }
                })
                .collect();
            (cap, samples)
        },
        |(cap, samples)| {
            let mut ring = RingSeries::new(*cap);
            for (i, s) in samples.iter().enumerate() {
                ring.push(*s);
                let latest = ring.latest().ok_or("latest() empty after a push")?;
                if latest.vt != s.vt || latest.block.tasks_done != s.block.tasks_done {
                    return Err(format!("push {i}: latest() is not the newest sample"));
                }
                if ring.len() != (i + 1).min(*cap) {
                    return Err(format!("push {i}: len {} != min(n, cap)", ring.len()));
                }
            }
            if ring.pushed() != samples.len() as u64 {
                return Err(format!("pushed() {} != {}", ring.pushed(), samples.len()));
            }
            let kept = ring.to_vec();
            let want = &samples[samples.len() - samples.len().min(*cap)..];
            if kept.len() != want.len() {
                return Err(format!("retained {} samples, want {}", kept.len(), want.len()));
            }
            for (k, w) in kept.iter().zip(want) {
                if k.vt != w.vt || k.block.tasks_done != w.block.tasks_done {
                    return Err("retained window is not the newest suffix".into());
                }
            }
            if !kept.windows(2).all(|w| w[0].vt <= w[1].vt) {
                return Err("iteration order lost time order".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_win_size_must_exceed_floor() {
    PropRunner::new(50).check(
        "config validation",
        |rng| rng.below(10_000) as usize,
        |&win_size| {
            let cfg = JobConfig { win_size, ..Default::default() };
            let ok = cfg.validate().is_ok();
            if (win_size >= 4096) == ok {
                Ok(())
            } else {
                Err(format!("win_size {win_size}: validate() == {ok}"))
            }
        },
    );
}

#[test]
fn prop_timelines_monotonic_nonoverlapping_all_usecases_and_routes() {
    // Trace integrity as an exhaustive sweep: every registered use-case
    // × every shuffle route, on both backends.  A rank's virtual clock
    // never goes backwards, so its phase events and op spans must be
    // t0-monotonic with no interval overlapping its predecessor, and
    // every interval must be non-empty and end within the rank's
    // elapsed time.
    use mr1s::mapreduce::RouteConfig;
    use mr1s::metrics::tracer::op;
    use mr1s::usecases::REGISTRY;
    use mr1s::workload::{generate_corpus, CorpusSpec};

    let path = std::env::temp_dir().join(format!("mr1s-prop-trace-{}", std::process::id()));
    generate_corpus(&path, &CorpusSpec { bytes: 120_000, seed: 21, ..Default::default() })
        .unwrap();
    let routes = [
        RouteConfig::Modulo,
        RouteConfig::Planned { split: RouteConfig::DEFAULT_SPLIT },
        RouteConfig::Coded { r: 2 },
    ];
    for entry in REGISTRY {
        for route in routes {
            for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
                let cfg = JobConfig {
                    input: path.clone(),
                    task_size: 16 << 10,
                    win_size: 16 << 10,
                    chunk_size: 4 << 10,
                    route,
                    ..Default::default()
                };
                let out = Job::new((entry.make)(), cfg)
                    .unwrap()
                    .run(backend, 4, CostModel::default())
                    .unwrap();
                for (rank, tl) in out.report.timelines.iter().enumerate() {
                    let ctx =
                        format!("{} {} {route:?} rank {rank}", entry.name, backend.name());
                    let end = out.report.rank_elapsed_ns[rank];
                    for w in tl.windows(2) {
                        assert!(
                            w[0].t1 <= w[1].t0,
                            "overlapping events {:?} / {:?} ({ctx})",
                            w[0],
                            w[1]
                        );
                    }
                    for e in tl {
                        assert!(e.t0 < e.t1, "empty event {e:?} ({ctx})");
                        assert!(e.t1 <= end, "event past rank end {e:?} ({ctx})");
                    }
                }
                for (rank, spans) in out.report.spans.iter().enumerate() {
                    let ctx =
                        format!("{} {} {route:?} rank {rank}", entry.name, backend.name());
                    // Spans are pushed when the operation completes, so
                    // the recording order is t1-monotonic (an attributed
                    // wait may *contain* the protocol ops it blocked on,
                    // so t0 order is not the invariant).
                    for w in spans.windows(2) {
                        assert!(
                            w[0].t1 <= w[1].t1,
                            "spans out of completion order {:?} / {:?} ({ctx})",
                            w[0],
                            w[1]
                        );
                    }
                    for s in spans {
                        assert!(s.t0 < s.t1, "empty span {s:?} ({ctx})");
                        assert!(s.t1 <= out.report.rank_elapsed_ns[rank], "{ctx}");
                        if s.op == op::WAIT {
                            assert!(s.cause.is_some(), "uncaused wait span ({ctx})");
                        }
                    }
                }
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn prop_ledger_diff_exact_all_usecases_backends_routes() {
    // The differ's exactness invariant as an exhaustive sweep (DESIGN.md
    // §12): for every registered use-case × both backends × every
    // shuffle route, take two runs with different configs and check
    // that (a) each rank ledger decomposes its elapsed time exactly,
    // (b) the diff components sum to the elapsed delta with zero
    // residual in both directions, (c) a self-diff is all-zeros with no
    // causes, and (d) the record survives a JSON round trip losslessly.
    use mr1s::mapreduce::RouteConfig;
    use mr1s::metrics::diff::diff_ledgers;
    use mr1s::metrics::ledger::{RunLedger, RunRecord};
    use mr1s::usecases::REGISTRY;
    use mr1s::workload::{generate_corpus, CorpusSpec};

    let path = std::env::temp_dir().join(format!("mr1s-prop-ledger-{}", std::process::id()));
    generate_corpus(&path, &CorpusSpec { bytes: 60_000, seed: 23, ..Default::default() })
        .unwrap();
    let routes = [
        RouteConfig::Modulo,
        RouteConfig::Planned { split: RouteConfig::DEFAULT_SPLIT },
        RouteConfig::Coded { r: 2 },
    ];
    for entry in REGISTRY {
        for route in routes {
            for backend in [BackendKind::OneSided, BackendKind::TwoSided] {
                let ctx = format!("{} {} {route:?}", entry.name, backend.name());
                let run = |task_size: usize| {
                    let cfg = JobConfig {
                        input: path.clone(),
                        task_size,
                        win_size: 16 << 10,
                        chunk_size: 4 << 10,
                        route,
                        ..Default::default()
                    };
                    Job::new((entry.make)(), cfg)
                        .unwrap()
                        .run(backend, 4, CostModel::default())
                        .unwrap()
                };
                let route_label = route.label();
                let record = |out: &mr1s::mapreduce::JobOutput| {
                    RunRecord::from_report("job", entry.name, &route_label, &out.report)
                };
                let (out_a, out_b) = (run(16 << 10), run(8 << 10));
                let (rec_a, rec_b) = (record(&out_a), record(&out_b));

                for rec in [&rec_a, &rec_b] {
                    assert_eq!(rec.untracked_ns(), 0, "{ctx}: crit path must tile makespan");
                    for (i, rank) in rec.ranks.iter().enumerate() {
                        assert_eq!(
                            rank.components_total_ns(),
                            rank.elapsed_ns,
                            "{ctx}: rank {i} decomposition inexact"
                        );
                    }
                }

                let mut a = RunLedger::new("prop", "a");
                a.push(rec_a);
                let mut b = RunLedger::new("prop", "b");
                b.push(rec_b);
                for (x, y) in [(&a, &b), (&b, &a)] {
                    let d = diff_ledgers(x, y);
                    assert_eq!(d.pairs.len(), 1, "{ctx}: pair must align");
                    let pair = &d.pairs[0];
                    assert_eq!(pair.residual_ns(), 0, "{ctx}: nonzero residual");
                    assert_eq!(
                        pair.components_delta_ns(),
                        pair.delta_elapsed_ns(),
                        "{ctx}: components must sum to the elapsed delta"
                    );
                }
                let d = diff_ledgers(&a, &a);
                assert!(
                    d.pairs[0].components.iter().all(|c| c.delta_ns() == 0),
                    "{ctx}: self-diff must be all-zeros"
                );
                assert!(d.top_causes(usize::MAX).is_empty(), "{ctx}: self-diff causes");

                let round = RunLedger::parse(&a.to_json())
                    .unwrap_or_else(|e| panic!("{ctx}: reparse failed: {e:?}"));
                assert_eq!(a, round, "{ctx}: JSON round trip must be lossless");
            }
        }
    }
    std::fs::remove_file(&path).ok();
}
