//! Runtime ↔ artifact integration: the L3 boundary with the AOT kernels.
//!
//! These tests require `make artifacts` to have run AND the real `xla`
//! bindings (not the in-tree stub); `Engine::load` fails in either case
//! and every test here skips (not fails), so `cargo test` stays green on
//! a fresh checkout.

use mr1s::mapreduce::job::cached_engine;
use mr1s::mapreduce::kv;
use mr1s::runtime::Engine;
use mr1s::testing::PropRunner;
use mr1s::workload::SplitMix64;

fn engine() -> Option<std::sync::Arc<Engine>> {
    let e = cached_engine();
    if e.is_none() {
        eprintln!("skipping: PJRT artifacts unavailable (run `make artifacts` with real xla bindings)");
    }
    e
}

#[test]
fn artifacts_present_implies_engine_loads() {
    // Guards the skip logic itself: with real bindings and artifacts on
    // disk, a broken engine must FAIL the suite, not silently skip it.
    let dir = mr1s::mapreduce::job::default_artifact_dir();
    if !dir.join("manifest.txt").exists() {
        return; // fresh checkout: nothing to assert
    }
    if let Err(e) = Engine::load(&dir) {
        let msg = e.to_string();
        assert!(
            msg.contains("xla stub"),
            "artifacts present but engine failed to load: {msg}"
        );
    }
}

#[test]
fn kernel_hash_equals_scalar_on_random_tokens() {
    let Some(eng) = engine() else { return };
    PropRunner::new(20).check(
        "kernel==scalar hash",
        |rng| {
            let n = 1 + rng.below(4096) as usize;
            (0..n)
                .map(|_| {
                    let len = rng.below(40) as usize; // > WIDTH gets truncated
                    (0..len).map(|_| rng.below(256) as u8).collect::<Vec<u8>>()
                })
                .collect::<Vec<_>>()
        },
        |tokens| {
            let refs: Vec<&[u8]> = tokens.iter().map(Vec::as_slice).collect();
            let (kh, kc) = eng.hash_batch(&refs).map_err(|e| e.to_string())?;
            let (sh, sc) = Engine::hash_batch_scalar(&refs, 256);
            if kh != sh {
                return Err("hash vectors differ".into());
            }
            if kc != sc {
                return Err("histograms differ".into());
            }
            Ok(())
        },
    );
}

#[test]
fn kernel_sort_perm_is_a_sorting_permutation() {
    let Some(eng) = engine() else { return };
    PropRunner::new(20).check(
        "sort_perm validity",
        |rng| {
            let n = 1 + rng.below(4096) as usize;
            (0..n).map(|_| rng.next_u64()).collect::<Vec<u64>>()
        },
        |keys| {
            let perm = eng.sort_perm(keys).map_err(|e| e.to_string())?;
            if perm.len() != keys.len() {
                return Err("length mismatch".into());
            }
            let mut seen = vec![false; keys.len()];
            for &p in &perm {
                if seen[p as usize] {
                    return Err("duplicate index".into());
                }
                seen[p as usize] = true;
            }
            let sorted: Vec<u64> = perm.iter().map(|&p| keys[p as usize]).collect();
            if !sorted.windows(2).all(|w| w[0] <= w[1]) {
                return Err("not sorted".into());
            }
            Ok(())
        },
    );
}

#[test]
fn kernel_combine_sort_matches_scalar_fold() {
    let Some(eng) = engine() else { return };
    PropRunner::new(20).check(
        "combine_sort==scalar",
        |rng| {
            let n = 1 + rng.below(4096) as usize;
            let keyspace = 1 + rng.below(200);
            (0..n)
                .map(|_| (rng.below(keyspace), rng.below(1000) as u32))
                .collect::<Vec<(u64, u32)>>()
        },
        |pairs| {
            let keys: Vec<u64> = pairs.iter().map(|(k, _)| *k).collect();
            let vals: Vec<u32> = pairs.iter().map(|(_, v)| *v).collect();
            let (uk, uv) = eng.combine_sort_block(&keys, &vals).map_err(|e| e.to_string())?;
            // Scalar fold.
            let mut map = std::collections::BTreeMap::new();
            for (k, v) in pairs {
                *map.entry(*k).or_insert(0u64) += u64::from(*v);
            }
            let want_k: Vec<u64> = map.keys().copied().collect();
            let want_v: Vec<u32> = map.values().map(|&v| v as u32).collect();
            if uk != want_k || uv != want_v {
                return Err(format!("fold mismatch: {} vs {} uniques", uk.len(), want_k.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn kernel_hash_agrees_with_rust_fnv_reference() {
    let Some(eng) = engine() else { return };
    // Golden vectors through all three representations: rust scalar,
    // kernel, and the published FNV test vector.
    let tokens: Vec<&[u8]> = vec![b"hello", b"wikipedia", b"a", b""];
    let (kh, _) = eng.hash_batch(&tokens).unwrap();
    assert_eq!(kh[0], 0xA430D84680AABD0B);
    assert_eq!(kh[0], kv::hash_key(b"hello"));
    assert_eq!(kh[1], kv::hash_key(b"wikipedia"));
    assert_eq!(kh[2], kv::hash_key(b"a"));
    assert_eq!(kh[3], 0, "padding/empty rows hash to 0 by contract");
}

#[test]
fn engine_rejects_oversized_inputs() {
    let Some(eng) = engine() else { return };
    let g = eng.geometry();
    let too_many: Vec<&[u8]> = vec![b"x"; g.batch + 1];
    assert!(eng.hash_batch(&too_many).is_err());
    let keys = vec![0u64; g.sort_batch + 1];
    assert!(eng.sort_perm(&keys).is_err());
}

#[test]
fn full_job_through_kernels_is_deterministic() {
    let Some(_) = engine() else { return };
    use mr1s::mapreduce::{BackendKind, Job, JobConfig};
    use mr1s::sim::CostModel;
    use mr1s::usecases::WordCount;
    use mr1s::workload::{generate_corpus, CorpusSpec};
    use std::sync::Arc;

    let p = std::env::temp_dir().join(format!("mr1s-rt-{}", std::process::id()));
    generate_corpus(&p, &CorpusSpec { bytes: 100_000, seed: 99, ..Default::default() }).unwrap();
    let cfg = JobConfig {
        input: p.clone(),
        task_size: 16 << 10,
        use_kernel: true,
        ..Default::default()
    };
    let run = |cfg: JobConfig| {
        Job::new(Arc::new(WordCount), cfg)
            .unwrap()
            .run(BackendKind::OneSided, 4, CostModel::default())
            .unwrap()
    };
    let a = run(cfg.clone());
    let b = run(cfg);
    assert_eq!(a.result, b.result, "kernel-path results must be deterministic");
    assert_eq!(a.report.unique_keys, b.report.unique_keys);
    let _ = SplitMix64::new(0); // keep the import used on skip paths
    std::fs::remove_file(&p).ok();
}
