//! Inert stand-in for the real `xla` PJRT bindings.
//!
//! The reproduction's L1/L2 kernel path executes AOT-compiled HLO through
//! PJRT via the `xla` bindings, which require the XLA C++ runtime — not
//! available in offline CI images.  This crate mirrors exactly the API
//! surface `rust/src/runtime/engine.rs` consumes, with a client
//! constructor that always fails, so:
//!
//! * the crate builds with zero network / native dependencies;
//! * `Engine::load` returns `Err`, `cached_engine()` returns `None`, and
//!   every job transparently takes the scalar fallback path (the same
//!   path the `--no-kernel` flag forces);
//! * kernel-dependent tests skip themselves instead of failing.
//!
//! To enable the kernels, point the `xla` path dependency in the root
//! `Cargo.toml` at the real bindings and run `make artifacts`.

/// Error type matching the real bindings' surface.
#[derive(Debug)]
pub struct Error(String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable() -> Error {
    Error("xla stub: PJRT runtime not available in this build (scalar path only)".to_string())
}

type Result<T> = std::result::Result<T, Error>;

/// Element types used by the engine's literals.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    /// Unsigned 8-bit.
    U8,
    /// Unsigned 32-bit.
    U32,
    /// Unsigned 64-bit.
    U64,
    /// Signed 32-bit.
    S32,
}

/// Host-side literal (never actually constructed by the stub).
#[derive(Debug)]
pub struct Literal(());

impl Literal {
    /// Build a literal from a shape and raw bytes.
    pub fn create_from_shape_and_untyped_data(
        _ty: ElementType,
        _dims: &[usize],
        _data: &[u8],
    ) -> Result<Literal> {
        Err(unavailable())
    }

    /// Build a rank-1 literal from a typed slice.
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal(())
    }

    /// Reshape to `dims`.
    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Parsed HLO module.
#[derive(Debug)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// Computation wrapper around an HLO module.
#[derive(Debug)]
pub struct XlaComputation(());

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer returned by executions.
#[derive(Debug)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// Compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with the given arguments.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient(());

impl PjRtClient {
    /// CPU client — always fails in the stub, routing callers to the
    /// scalar fallback.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    /// Compile a computation.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}
