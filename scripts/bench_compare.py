#!/usr/bin/env python3
"""Perf-regression gate over the bench JSON summaries.

Every bench binary writes a ``BENCH_<name>.json`` summary (see
``rust/src/bench.rs``): ``{"bench": .., "schema": 2, "git_sha": ..,
"config": .., "samples": [{"name", "mean", "stddev", "n"}, ..]}`` with
means in virtual nanoseconds for whole-job benches.  The ``schema`` /
``git_sha`` / ``config`` keys are run metadata: this gate prints them
for provenance and excludes them from all regression math, so v1
summaries (no metadata) and v2 summaries compare interchangeably.  Virtual time is simulated, so run-to-run noise is tiny and a
tight threshold is meaningful — the default fails on >10% growth of any
``*_elapsed_ns`` sample versus the committed baseline in
``rust/benches/baselines/``.

Usage (CI runs this right after the smoke benches)::

    python3 scripts/bench_compare.py \
        [--fresh-dir .] [--baseline-dir rust/benches/baselines] \
        [--threshold 0.10] [--allow-missing] [--update]

Exit codes: 0 = no regression, 1 = regression (or missing baseline
without ``--allow-missing``), 2 = usage/IO error.

``--allow-missing`` keeps the gate green while a bench has no committed
baseline yet (the bootstrap state: baselines are produced by a
toolchain-equipped run and committed from its artifacts; see
``rust/benches/baselines/README.md``).  ``--update`` copies the fresh
summaries over the baselines instead of comparing — the refresh path.

``--self-check`` ignores the directories, synthesizes a baseline and a
regressed fresh summary in a temp dir, and exits 0 only if the gate
catches the injected regression — CI runs it so the gate's failure mode
is itself tested on every push.
"""

import argparse
import json
import os
import shutil
import sys
import tempfile

# Samples whose mean is a virtual duration: the regression axis.  Other
# samples (byte counts, ratios) are informational and not gated — byte
# accounting changes legitimately when a bench's sweep changes.
TIME_SUFFIXES = ("_elapsed_ns",)

# Top-level run-metadata keys (schema v2): carried for provenance,
# never compared.  Any other unknown top-level key is ignored outright.
META_KEYS = ("schema", "git_sha", "config")


def load_summary(path):
    """Parse one BENCH_*.json into (bench, {sample_name: mean}, meta)."""
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    samples = {}
    for s in doc.get("samples", []):
        samples[s["name"]] = float(s["mean"])
    meta = {k: doc[k] for k in META_KEYS if k in doc}
    return doc.get("bench", os.path.basename(path)), samples, meta


def bench_files(directory):
    """BENCH_*.json files directly inside ``directory``, sorted."""
    try:
        names = sorted(os.listdir(directory))
    except OSError:
        return []
    return [
        os.path.join(directory, n)
        for n in names
        if n.startswith("BENCH_") and n.endswith(".json")
    ]


def compare(baseline, fresh, threshold):
    """Compare two {name: mean} maps.

    Returns (regressions, improvements, notes): regressions are
    ``(name, base, new, ratio)`` for time samples growing beyond the
    threshold; improvements mirror them for shrinkage; notes flag
    samples present on one side only.
    """
    regressions, improvements, notes = [], [], []
    for name, base in sorted(baseline.items()):
        if not name.endswith(TIME_SUFFIXES):
            continue
        if name not in fresh:
            notes.append(f"sample '{name}' missing from fresh run")
            continue
        new = fresh[name]
        if base <= 0:
            notes.append(f"sample '{name}' has non-positive baseline {base}")
            continue
        ratio = new / base
        if ratio > 1.0 + threshold:
            regressions.append((name, base, new, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, base, new, ratio))
    for name in sorted(set(fresh) - set(baseline)):
        if name.endswith(TIME_SUFFIXES):
            notes.append(f"sample '{name}' is new (no baseline)")
    return regressions, improvements, notes


def run_compare(fresh_dir, baseline_dir, threshold, allow_missing):
    """Compare every fresh summary against its baseline; return exit code."""
    fresh_paths = bench_files(fresh_dir)
    if not fresh_paths:
        print(f"error: no BENCH_*.json under '{fresh_dir}'", file=sys.stderr)
        return 2
    failed = False
    for fresh_path in fresh_paths:
        base_path = os.path.join(baseline_dir, os.path.basename(fresh_path))
        bench, fresh, meta = load_summary(fresh_path)
        if meta:
            rendered = " ".join(f"{k}={meta[k]}" for k in META_KEYS if k in meta)
            print(f"meta  {bench}: {rendered}")
        if not os.path.exists(base_path):
            msg = f"{bench}: no baseline at {base_path}"
            if allow_missing:
                print(f"SKIP  {msg} (--allow-missing)")
                continue
            print(f"FAIL  {msg}", file=sys.stderr)
            failed = True
            continue
        _, baseline, _ = load_summary(base_path)
        regressions, improvements, notes = compare(baseline, fresh, threshold)
        for note in notes:
            print(f"note  {bench}: {note}")
        for name, base, new, ratio in improvements:
            print(
                f"ok    {bench}: {name} improved "
                f"{base / 1e6:.3f} -> {new / 1e6:.3f} ms ({(1 - ratio) * 100:.1f}% faster)"
            )
        for name, base, new, ratio in regressions:
            print(
                f"FAIL  {bench}: {name} regressed "
                f"{base / 1e6:.3f} -> {new / 1e6:.3f} ms "
                f"(+{(ratio - 1) * 100:.1f}% > {threshold * 100:.0f}% threshold)",
                file=sys.stderr,
            )
        if regressions:
            failed = True
        else:
            gated = sum(1 for n in baseline if n.endswith(TIME_SUFFIXES))
            print(f"ok    {bench}: {gated} time samples within {threshold * 100:.0f}%")
    return 1 if failed else 0


def run_update(fresh_dir, baseline_dir):
    """Copy fresh summaries over the committed baselines."""
    fresh_paths = bench_files(fresh_dir)
    if not fresh_paths:
        print(f"error: no BENCH_*.json under '{fresh_dir}'", file=sys.stderr)
        return 2
    os.makedirs(baseline_dir, exist_ok=True)
    for path in fresh_paths:
        dest = os.path.join(baseline_dir, os.path.basename(path))
        shutil.copyfile(path, dest)
        print(f"updated {dest}")
    return 0


def write_summary(path, bench, samples, meta=None):
    doc = {
        "bench": bench,
        "samples": [
            {"name": n, "mean": m, "stddev": 0.0, "n": 1} for n, m in samples.items()
        ],
    }
    doc.update(meta or {})
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)


def run_self_check(threshold):
    """Prove the gate trips on an injected regression (and only then)."""
    with tempfile.TemporaryDirectory(prefix="bench-compare-") as tmp:
        base_dir = os.path.join(tmp, "baselines")
        fresh_dir = os.path.join(tmp, "fresh")
        os.makedirs(base_dir)
        os.makedirs(fresh_dir)
        base = {"job_elapsed_ns": 1e9, "job_bytes": 5e6}
        write_summary(os.path.join(base_dir, "BENCH_selfcheck.json"), "selfcheck", base)

        # A clean run well inside the threshold must pass — stamped with
        # v2 metadata against a v1 (metadata-free) baseline, proving the
        # metadata keys never enter the regression math.
        ok = dict(base, job_elapsed_ns=base["job_elapsed_ns"] * (1 + threshold / 2))
        meta = {"schema": 2, "git_sha": "selfcheck", "config": "synthetic"}
        write_summary(os.path.join(fresh_dir, "BENCH_selfcheck.json"), "selfcheck", ok, meta)
        if run_compare(fresh_dir, base_dir, threshold, False) != 0:
            print("self-check: clean run was rejected", file=sys.stderr)
            return 1

        # ...and an injected regression just past it must fail.
        bad = dict(base, job_elapsed_ns=base["job_elapsed_ns"] * (1 + threshold * 2))
        write_summary(os.path.join(fresh_dir, "BENCH_selfcheck.json"), "selfcheck", bad)
        if run_compare(fresh_dir, base_dir, threshold, False) != 1:
            print("self-check: injected regression was NOT caught", file=sys.stderr)
            return 1
    print("self-check: gate passes clean runs and catches injected regressions")
    return 0


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--fresh-dir", default=".", help="directory with fresh BENCH_*.json")
    parser.add_argument(
        "--baseline-dir",
        default="rust/benches/baselines",
        help="directory with committed baseline BENCH_*.json",
    )
    parser.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="fractional virtual-time growth that counts as a regression",
    )
    parser.add_argument(
        "--allow-missing",
        action="store_true",
        help="skip benches with no committed baseline instead of failing",
    )
    parser.add_argument(
        "--update",
        action="store_true",
        help="overwrite the baselines with the fresh summaries",
    )
    parser.add_argument(
        "--self-check",
        action="store_true",
        help="verify the gate catches a synthetic injected regression",
    )
    args = parser.parse_args(argv)
    if args.threshold <= 0:
        parser.error("--threshold must be positive")
    if args.self_check:
        return run_self_check(args.threshold)
    if args.update:
        return run_update(args.fresh_dir, args.baseline_dir)
    return run_compare(args.fresh_dir, args.baseline_dir, args.threshold, args.allow_missing)


if __name__ == "__main__":
    sys.exit(main())
