#!/usr/bin/env python3
"""Perf-regression gate over the bench JSON summaries.

Every bench binary writes a ``BENCH_<name>.json`` summary (see
``rust/src/bench.rs``): ``{"bench": .., "schema": 2, "git_sha": ..,
"config": .., "samples": [{"name", "mean", "stddev", "n"}, ..]}`` with
means in virtual nanoseconds for whole-job benches.  The ``schema`` /
``git_sha`` / ``config`` keys are run metadata: this gate prints them
for provenance and excludes them from all regression math, so v1
summaries (no metadata) and v2 summaries compare interchangeably.  Virtual time is simulated, so run-to-run noise is tiny and a
tight threshold is meaningful — the default fails on >10% growth of any
``*_elapsed_ns`` sample versus the committed baseline in
``rust/benches/baselines/``.

Usage (CI runs this right after the smoke benches)::

    python3 scripts/bench_compare.py \
        [--fresh-dir .] [--baseline-dir rust/benches/baselines] \
        [--threshold 0.10] [--allow-missing] [--update]

Exit codes: 0 = no regression, 1 = regression (or missing baseline
without ``--allow-missing``), 2 = usage/IO error.

``--allow-missing`` keeps the gate green while a bench has no committed
baseline yet (the bootstrap state: baselines are produced by a
toolchain-equipped run and committed from its artifacts; see
``rust/benches/baselines/README.md``).  ``--update`` copies the fresh
summaries over the baselines instead of comparing — the refresh path.

``--self-check`` ignores the directories, synthesizes a baseline and a
regressed fresh summary in a temp dir, and exits 0 only if the gate
catches the injected regression — CI runs it so the gate's failure mode
is itself tested on every push.  It also injects a synthetic
single-cause ledger regression and asserts the differ ranks that cause
first with zero residual.

``--ledger-dir DIR`` points at the fresh ``LEDGER_<bench>.json`` run
ledgers (written by the benches beside their summaries; see
``rust/src/metrics/ledger.rs``).  When a bench FAILs the gate and both
sides have a ledger (baselines live in ``<baseline-dir>/ledgers/``),
the failure is annotated with differential attribution: the makespan
delta of every regressed run decomposed into critical-path causes that
sum to the delta exactly.  Missing baseline ledgers are reported as a
bootstrap note, never an error.
"""

import argparse
import json
import os
import shutil
import sys
import tempfile

# Samples whose mean is a virtual duration: the regression axis.  Other
# samples (byte counts, ratios) are informational and not gated — byte
# accounting changes legitimately when a bench's sweep changes.
TIME_SUFFIXES = ("_elapsed_ns",)

# Top-level run-metadata keys (schema v2): carried for provenance,
# never compared.  Any other unknown top-level key is ignored outright.
META_KEYS = ("schema", "git_sha", "config")

# Run-ledger schema this differ understands (mirrors
# LEDGER_SCHEMA_VERSION in rust/src/metrics/ledger.rs).
LEDGER_SCHEMA = 1

# Component label for makespan ns the critical path does not tile
# (mirrors UNTRACKED in rust/src/metrics/diff.rs).
UNTRACKED = "untracked"


def load_summary(path):
    """Parse one BENCH_*.json into (bench, {sample_name: mean}, meta)."""
    with open(path, "r", encoding="utf-8") as f:
        doc = json.load(f)
    samples = {}
    for s in doc.get("samples", []):
        samples[s["name"]] = float(s["mean"])
    meta = {k: doc[k] for k in META_KEYS if k in doc}
    return doc.get("bench", os.path.basename(path)), samples, meta


def bench_files(directory):
    """BENCH_*.json files directly inside ``directory``, sorted."""
    try:
        names = sorted(os.listdir(directory))
    except OSError:
        return []
    return [
        os.path.join(directory, n)
        for n in names
        if n.startswith("BENCH_") and n.endswith(".json")
    ]


def compare(baseline, fresh, threshold):
    """Compare two {name: mean} maps.

    Returns (regressions, improvements, notes): regressions are
    ``(name, base, new, ratio)`` for time samples growing beyond the
    threshold; improvements mirror them for shrinkage; notes flag
    samples present on one side only.
    """
    regressions, improvements, notes = [], [], []
    for name, base in sorted(baseline.items()):
        if not name.endswith(TIME_SUFFIXES):
            continue
        if name not in fresh:
            notes.append(f"sample '{name}' missing from fresh run")
            continue
        new = fresh[name]
        if base <= 0:
            notes.append(f"sample '{name}' has non-positive baseline {base}")
            continue
        ratio = new / base
        if ratio > 1.0 + threshold:
            regressions.append((name, base, new, ratio))
        elif ratio < 1.0 - threshold:
            improvements.append((name, base, new, ratio))
    for name in sorted(set(fresh) - set(baseline)):
        if name.endswith(TIME_SUFFIXES):
            notes.append(f"sample '{name}' is new (no baseline)")
    return regressions, improvements, notes


def load_ledger(path):
    """Parse one LEDGER_*.json; returns the document or None on error.

    Lenient by design: only the alignment keys, ``elapsed_ns`` and the
    ``crit`` section are required per run — attribution must work on
    hand-written fixtures and future schema extensions alike.
    """
    try:
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"note  ledger {path}: unreadable ({e})", file=sys.stderr)
        return None
    if doc.get("schema") != LEDGER_SCHEMA:
        print(
            f"note  ledger {path}: schema {doc.get('schema')} != {LEDGER_SCHEMA}",
            file=sys.stderr,
        )
        return None
    return doc


def ledger_run_key(run):
    """The alignment key (mirrors RunKey in rust/src/metrics/ledger.rs)."""
    return (
        run.get("tag"),
        run.get("usecase"),
        run.get("backend"),
        run.get("route"),
        run.get("nranks"),
    )


def ledger_components(run):
    """The additive decomposition of one run: crit labels + untracked.

    All values are exact ints, so the diff algebra below telescopes to
    the elapsed delta with zero residual — same invariant as the Rust
    differ.
    """
    crit = run.get("crit", {})
    comps = {label: int(ns) for label, ns in crit.get("labels", {}).items()}
    comps[UNTRACKED] = int(run["elapsed_ns"]) - int(crit.get("total_ns", 0))
    return comps


def diff_ledgers(a_doc, b_doc):
    """Align two ledger documents by run key and decompose each pair.

    Returns a list of pair dicts: ``key`` (rendered), ``elapsed_a``/
    ``elapsed_b``, ``components`` ({label: (a, b, delta)}), and
    ``residual`` (always 0 for well-formed ledgers — asserted by the
    self-check and the pytest suite, surfaced here for fixtures).
    """
    b_runs = {ledger_run_key(r): r for r in b_doc.get("runs", [])}
    pairs = []
    for ra in a_doc.get("runs", []):
        rb = b_runs.get(ledger_run_key(ra))
        if rb is None:
            continue
        ca, cb = ledger_components(ra), ledger_components(rb)
        components = {
            label: (ca.get(label, 0), cb.get(label, 0), cb.get(label, 0) - ca.get(label, 0))
            for label in sorted(set(ca) | set(cb))
        }
        delta = int(rb["elapsed_ns"]) - int(ra["elapsed_ns"])
        pairs.append(
            {
                "key": "{} [{} {} {} {}r]".format(*ledger_run_key(ra)),
                "tag": ra.get("tag"),
                "elapsed_a": int(ra["elapsed_ns"]),
                "elapsed_b": int(rb["elapsed_ns"]),
                "components": components,
                "residual": delta - sum(d for _, _, d in components.values()),
            }
        )
    return pairs


def top_causes(pairs, k=5):
    """Globally ranked ``(key, label, delta)``, most-regressing first."""
    causes = [
        (p["key"], label, delta)
        for p in pairs
        for label, (_, _, delta) in p["components"].items()
        if delta != 0
    ]
    causes.sort(key=lambda c: (-c[2], c[1], c[0]))
    return causes[:k]


def print_attribution(bench, pairs, tags=None, top=5):
    """Print the attribution block for a failed bench.

    ``tags`` narrows to the regressed runs (None = all pairs).
    """
    shown = [p for p in pairs if tags is None or p["tag"] in tags] or pairs
    for p in shown:
        delta = p["elapsed_b"] - p["elapsed_a"]
        print(
            f"why   {bench}: {p['key']} elapsed "
            f"{p['elapsed_a'] / 1e6:.3f} -> {p['elapsed_b'] / 1e6:.3f} ms "
            f"({delta:+d} ns, residual {p['residual']} ns)"
        )
        ranked = sorted(
            p["components"].items(), key=lambda kv: (-kv[1][2], kv[0])
        )
        for label, (a, b, d) in ranked:
            if a == 0 and b == 0:
                continue
            print(f"why   {bench}:   {label:<18} {a:>14} -> {b:>14}  {d:>+14}")
    ranked = top_causes(shown, top)
    if ranked:
        lead_key, lead_label, lead_delta = ranked[0]
        print(
            f"why   {bench}: top regressing cause: {lead_label} "
            f"({lead_delta:+d} ns on {lead_key})"
        )


def attribute_failure(bench, fresh_path, ledger_dir, baseline_dir, regressed_names):
    """On a gate FAIL, print ledger attribution if both sides have one."""
    ledger_name = os.path.basename(fresh_path).replace("BENCH_", "LEDGER_", 1)
    fresh_ledger_path = os.path.join(ledger_dir, ledger_name)
    base_ledger_path = os.path.join(baseline_dir, "ledgers", ledger_name)
    if not os.path.exists(fresh_ledger_path):
        print(f"note  {bench}: no fresh ledger at {fresh_ledger_path}; cannot attribute")
        return
    if not os.path.exists(base_ledger_path):
        print(
            f"note  {bench}: no baseline ledger at {base_ledger_path} "
            "(bootstrap: commit one from a trusted run to enable attribution)"
        )
        return
    base_doc = load_ledger(base_ledger_path)
    fresh_doc = load_ledger(fresh_ledger_path)
    if base_doc is None or fresh_doc is None:
        return
    # Regressed sample names look like <tag>_elapsed_ns.
    tags = {n[: -len("_elapsed_ns")] for n in regressed_names}
    print_attribution(bench, diff_ledgers(base_doc, fresh_doc), tags)


def run_compare(fresh_dir, baseline_dir, threshold, allow_missing, ledger_dir=None):
    """Compare every fresh summary against its baseline; return exit code."""
    fresh_paths = bench_files(fresh_dir)
    if not fresh_paths:
        print(f"error: no BENCH_*.json under '{fresh_dir}'", file=sys.stderr)
        return 2
    failed = False
    for fresh_path in fresh_paths:
        base_path = os.path.join(baseline_dir, os.path.basename(fresh_path))
        bench, fresh, meta = load_summary(fresh_path)
        if meta:
            rendered = " ".join(f"{k}={meta[k]}" for k in META_KEYS if k in meta)
            print(f"meta  {bench}: {rendered}")
        if not os.path.exists(base_path):
            msg = f"{bench}: no baseline at {base_path}"
            if allow_missing:
                print(f"SKIP  {msg} (--allow-missing)")
                continue
            print(f"FAIL  {msg}", file=sys.stderr)
            failed = True
            continue
        _, baseline, _ = load_summary(base_path)
        regressions, improvements, notes = compare(baseline, fresh, threshold)
        for note in notes:
            print(f"note  {bench}: {note}")
        for name, base, new, ratio in improvements:
            print(
                f"ok    {bench}: {name} improved "
                f"{base / 1e6:.3f} -> {new / 1e6:.3f} ms ({(1 - ratio) * 100:.1f}% faster)"
            )
        for name, base, new, ratio in regressions:
            print(
                f"FAIL  {bench}: {name} regressed "
                f"{base / 1e6:.3f} -> {new / 1e6:.3f} ms "
                f"(+{(ratio - 1) * 100:.1f}% > {threshold * 100:.0f}% threshold)",
                file=sys.stderr,
            )
        if regressions:
            failed = True
            if ledger_dir is not None:
                attribute_failure(
                    bench,
                    fresh_path,
                    ledger_dir,
                    baseline_dir,
                    [name for name, _, _, _ in regressions],
                )
        else:
            gated = sum(1 for n in baseline if n.endswith(TIME_SUFFIXES))
            print(f"ok    {bench}: {gated} time samples within {threshold * 100:.0f}%")
    return 1 if failed else 0


def run_update(fresh_dir, baseline_dir):
    """Copy fresh summaries over the committed baselines."""
    fresh_paths = bench_files(fresh_dir)
    if not fresh_paths:
        print(f"error: no BENCH_*.json under '{fresh_dir}'", file=sys.stderr)
        return 2
    os.makedirs(baseline_dir, exist_ok=True)
    for path in fresh_paths:
        dest = os.path.join(baseline_dir, os.path.basename(path))
        shutil.copyfile(path, dest)
        print(f"updated {dest}")
    return 0


def write_summary(path, bench, samples, meta=None):
    doc = {
        "bench": bench,
        "samples": [
            {"name": n, "mean": m, "stddev": 0.0, "n": 1} for n, m in samples.items()
        ],
    }
    doc.update(meta or {})
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)


def write_ledger_doc(path, bench, runs):
    """Write a minimal schema-valid run ledger (self-check / fixtures)."""
    doc = {
        "ledger": bench,
        "schema": LEDGER_SCHEMA,
        "git_sha": "selfcheck",
        "config": "synthetic",
        "runs": runs,
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)


def synthetic_run(tag, elapsed_ns, labels):
    """A minimal ledger run record whose crit labels sum to elapsed."""
    return {
        "tag": tag,
        "usecase": "word-count",
        "backend": "mr-1s",
        "route": "modulo",
        "nranks": 4,
        "elapsed_ns": elapsed_ns,
        "crit": {"total_ns": sum(labels.values()), "labels": labels},
    }


def run_self_check(threshold):
    """Prove the gate trips on an injected regression (and only then)."""
    with tempfile.TemporaryDirectory(prefix="bench-compare-") as tmp:
        base_dir = os.path.join(tmp, "baselines")
        fresh_dir = os.path.join(tmp, "fresh")
        os.makedirs(base_dir)
        os.makedirs(fresh_dir)
        base = {"job_elapsed_ns": 1e9, "job_bytes": 5e6}
        write_summary(os.path.join(base_dir, "BENCH_selfcheck.json"), "selfcheck", base)

        # A clean run well inside the threshold must pass — stamped with
        # v2 metadata against a v1 (metadata-free) baseline, proving the
        # metadata keys never enter the regression math.
        ok = dict(base, job_elapsed_ns=base["job_elapsed_ns"] * (1 + threshold / 2))
        meta = {"schema": 2, "git_sha": "selfcheck", "config": "synthetic"}
        write_summary(os.path.join(fresh_dir, "BENCH_selfcheck.json"), "selfcheck", ok, meta)
        if run_compare(fresh_dir, base_dir, threshold, False) != 0:
            print("self-check: clean run was rejected", file=sys.stderr)
            return 1

        # ...and an injected regression just past it must fail.
        bad = dict(base, job_elapsed_ns=base["job_elapsed_ns"] * (1 + threshold * 2))
        write_summary(os.path.join(fresh_dir, "BENCH_selfcheck.json"), "selfcheck", bad)
        if run_compare(fresh_dir, base_dir, threshold, False) != 1:
            print("self-check: injected regression was NOT caught", file=sys.stderr)
            return 1

        # Ledger leg: inject a single-cause regression (only "barrier"
        # grows) and require the differ to (a) attribute it exactly —
        # zero residual — and (b) rank that cause first.
        os.makedirs(os.path.join(base_dir, "ledgers"))
        base_run = synthetic_run("job", 1_000_000_000, {"work": 900_000_000, "barrier": 100_000_000})
        bad_run = synthetic_run("job", 1_400_000_000, {"work": 900_000_000, "barrier": 500_000_000})
        write_ledger_doc(
            os.path.join(base_dir, "ledgers", "LEDGER_selfcheck.json"), "selfcheck", [base_run]
        )
        write_ledger_doc(
            os.path.join(fresh_dir, "LEDGER_selfcheck.json"), "selfcheck", [bad_run]
        )
        pairs = diff_ledgers(
            load_ledger(os.path.join(base_dir, "ledgers", "LEDGER_selfcheck.json")),
            load_ledger(os.path.join(fresh_dir, "LEDGER_selfcheck.json")),
        )
        if len(pairs) != 1 or pairs[0]["residual"] != 0:
            print("self-check: ledger diff residual is not zero", file=sys.stderr)
            return 1
        causes = top_causes(pairs)
        if not causes or causes[0][1] != "barrier" or causes[0][2] != 400_000_000:
            print(
                f"self-check: differ misattributed the injected cause: {causes}",
                file=sys.stderr,
            )
            return 1
        # The gate itself must print the attribution on the FAIL path.
        if run_compare(fresh_dir, base_dir, threshold, False, ledger_dir=fresh_dir) != 1:
            print("self-check: ledger-annotated gate run did not fail", file=sys.stderr)
            return 1
    print(
        "self-check: gate passes clean runs, catches injected regressions, "
        "and attributes them (single-cause 'barrier' regression correctly top-ranked)"
    )
    return 0


def main(argv=None):
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("--fresh-dir", default=".", help="directory with fresh BENCH_*.json")
    parser.add_argument(
        "--baseline-dir",
        default="rust/benches/baselines",
        help="directory with committed baseline BENCH_*.json",
    )
    parser.add_argument(
        "--threshold",
        type=float,
        default=0.10,
        help="fractional virtual-time growth that counts as a regression",
    )
    parser.add_argument(
        "--allow-missing",
        action="store_true",
        help="skip benches with no committed baseline instead of failing",
    )
    parser.add_argument(
        "--update",
        action="store_true",
        help="overwrite the baselines with the fresh summaries",
    )
    parser.add_argument(
        "--self-check",
        action="store_true",
        help="verify the gate catches a synthetic injected regression",
    )
    parser.add_argument(
        "--ledger-dir",
        default=None,
        help="directory with fresh LEDGER_*.json; annotate gate failures "
        "with differential attribution (baseline ledgers under "
        "<baseline-dir>/ledgers/)",
    )
    args = parser.parse_args(argv)
    if args.threshold <= 0:
        parser.error("--threshold must be positive")
    if args.self_check:
        return run_self_check(args.threshold)
    if args.update:
        return run_update(args.fresh_dir, args.baseline_dir)
    return run_compare(
        args.fresh_dir,
        args.baseline_dir,
        args.threshold,
        args.allow_missing,
        ledger_dir=args.ledger_dir,
    )


if __name__ == "__main__":
    sys.exit(main())
